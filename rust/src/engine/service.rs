//! The multi-tenant job service: many concurrent DAG jobs over **one**
//! shared serverless platform, KV cluster, and warm container pool.
//!
//! This is the regime the paper's FaaS pitch is actually about — "the
//! auto-scaling property of serverless platforms accommodates short
//! tasks and bursty workloads" — made a first-class scenario: jobs
//! arrive on a deterministic seeded **open-loop** schedule (they arrive
//! whether or not the platform has caught up, like real tenant traffic),
//! pass FIFO or fair **admission** with a queue-depth cap, and then run
//! as ordinary engine jobs whose executors contend for the shared warm
//! pool, platform concurrency cap, and KV shard NICs. Each job keeps its
//! own [`JobId`]-scoped KV arena, pub/sub namespace, and metrics hub, so
//! the service reports both per-job [`JobOutcome`]s (latency, queue
//! delay, cost, cold-start share) and fleet-level aggregates.
//!
//! Determinism: the virtual-time runtime plus seeded arrivals make an
//! entire service run — admissions, contention, completions — replayable
//! from its configuration alone; [`ServiceReport::render_trace`] is the
//! canonical artifact two runs of the same seed must agree on.
//!
//! Parallel simulation: [`ServiceConfig::sim_shards`] `> 1` runs the
//! fleet over N per-shard virtual-time executors (one OS thread each)
//! synchronized by conservative PDES (`rt::sharded`). Jobs partition
//! whole-job-per-shard by arrival index; the shared substrate — warm
//! pool, concurrency cap, KV shard NICs, arena registry — is reached
//! through gated rendezvous points, so the canonical trace stays
//! byte-identical to the serial path (swept per seed by
//! `sim::parallel_check`). Only the contention-free service regime is
//! supported; see [`JobService::run_sharded`].

use crate::core::{clock, JobId, SimConfig, SplitMix64, TaskId};
use crate::dag::Dag;
use crate::engine::driver::{EngineDriver, SharedPlatform};
use crate::engine::policy::SchedulingPolicy;
use crate::faas::Billing;
use crate::kvstore::{ArenaForensics, JobArena};
use crate::metrics::JobReport;
use crate::rt::sync::mpsc;
use std::collections::{HashMap, HashSet, VecDeque};
use std::sync::Arc;
use std::time::Duration;

/// One job submitted to the service.
pub struct JobRequest {
    /// Human-readable workload name ("tr-64", "rand-value", ...).
    pub name: String,
    /// Tenant the job belongs to (fair admission balances across
    /// tenants; several jobs may share one tenant).
    pub tenant: u32,
    /// Admission priority (higher wins) under [`Admission::Priority`]:
    /// the queue admits highest-priority first, and at `queue_cap` the
    /// lowest-priority *queued* job is shed to make room for a
    /// higher-priority arrival (running jobs are never preempted).
    /// Ignored by FIFO/fair admission.
    pub priority: u8,
    /// Per-job simulation seed (duration jitter etc.). The fault profile
    /// and platform knobs come from the service's base config.
    pub seed: u64,
    pub dag: Dag,
    pub policy: Arc<dyn SchedulingPolicy>,
}

/// Deterministic open-loop arrival schedules. Arrival *offsets* are
/// precomputed from the profile and the arrival seed, so the schedule
/// never depends on service progress (open loop) and replays exactly.
#[derive(Clone, Debug)]
pub enum ArrivalProfile {
    /// One job every `gap_ms`.
    Uniform { gap_ms: f64 },
    /// Exponential inter-arrival gaps with the given mean (a seeded
    /// Poisson process — the classic open-loop tenant model).
    Poisson { mean_gap_ms: f64 },
    /// Bursts of `burst` jobs spaced `intra_ms` apart, bursts separated
    /// by `idle_ms` — the bursty regime the paper's pitch names.
    Bursts {
        burst: usize,
        intra_ms: f64,
        idle_ms: f64,
    },
    /// Explicit arrival offsets (nanoseconds from session start), as
    /// captured by a live wall-clock session's [`SessionRecording`] —
    /// the replay half of the record→replay oracle. Offsets must be
    /// non-decreasing (a live session records them from one monotonic
    /// clock, so they are by construction); requests beyond the recorded
    /// length reuse the last offset. The arrival seed is ignored.
    Recorded { offsets_ns: Vec<u64> },
}

impl ArrivalProfile {
    /// Arrival offsets (from service start) for `n` jobs. Non-decreasing;
    /// the first job arrives at 0.
    pub fn arrival_offsets(&self, n: usize, seed: u64) -> Vec<Duration> {
        if let ArrivalProfile::Recorded { offsets_ns } = self {
            let last = offsets_ns.last().copied().unwrap_or(0);
            return (0..n)
                .map(|i| Duration::from_nanos(offsets_ns.get(i).copied().unwrap_or(last)))
                .collect();
        }
        let mut rng = SplitMix64::new(seed ^ 0xA881_11A1_5EED_u64);
        let mut t_ms = 0.0f64;
        (0..n)
            .map(|i| {
                if i > 0 {
                    t_ms += match self {
                        ArrivalProfile::Uniform { gap_ms } => gap_ms.max(0.0),
                        ArrivalProfile::Poisson { mean_gap_ms } => {
                            -mean_gap_ms.max(0.0) * (1.0 - rng.next_f64()).ln()
                        }
                        ArrivalProfile::Bursts {
                            burst,
                            intra_ms,
                            idle_ms,
                        } => {
                            if i % burst.max(1) == 0 {
                                idle_ms.max(0.0)
                            } else {
                                intra_ms.max(0.0)
                            }
                        }
                        ArrivalProfile::Recorded { .. } => {
                            unreachable!("recorded profiles return verbatim above")
                        }
                    };
                }
                Duration::from_secs_f64(t_ms * 1e-3)
            })
            .collect()
    }
}

/// Admission order for queued jobs.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Admission {
    /// Strict arrival order.
    Fifo,
    /// Balance across tenants: admit the queued job whose tenant has had
    /// the fewest jobs admitted so far (ties resolve in arrival order).
    Fair,
    /// Highest [`JobRequest::priority`] first (ties resolve in arrival
    /// order); at `queue_cap`, the lowest-priority queued job is shed to
    /// make room for a strictly-higher-priority arrival. Only *queued*
    /// jobs are ever preempted — running jobs always finish.
    Priority,
}

/// Why a job was shed instead of run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ShedReason {
    /// Arrived while the wait queue was at `queue_cap`.
    QueueFull,
    /// Displaced from the wait queue by a higher-priority arrival
    /// ([`Admission::Priority`] only).
    Preempted,
    /// Its tenant's accumulated cost reached the per-tenant dollar
    /// budget ([`ServiceConfig::tenant_budget_usd`]).
    Budget,
}

impl std::fmt::Display for ShedReason {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            ShedReason::QueueFull => "queue-full",
            ShedReason::Preempted => "preempted",
            ShedReason::Budget => "budget",
        })
    }
}

/// One shed (never-started) job. Shed jobs acquire **no** substrate: no
/// KV arena, no channel namespace, no metrics hub — the regression tests
/// assert the registries stay empty.
#[derive(Clone, Debug)]
pub struct Shed {
    pub job: JobId,
    pub name: String,
    pub tenant: u32,
    pub priority: u8,
    pub reason: ShedReason,
}

/// One submission into a live (wall-clock) session: the built request
/// plus the raw spec string it was built from. The spec is recorded
/// verbatim so a virtual-time replay can rebuild the identical request
/// through the same deterministic spec parser.
pub struct LiveSubmission {
    pub req: JobRequest,
    pub spec: String,
}

/// What a live session records about one submission — everything a
/// replay needs to rebuild it.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct RecordedJob {
    /// Arrival offset from session start, nanoseconds (monotonic — a
    /// live session stamps every arrival from one wall clock).
    pub offset_ns: u64,
    /// The raw job spec as submitted; replay rebuilds the request from
    /// this through the same parser the front door used.
    pub spec: String,
    pub name: String,
    pub tenant: u32,
    pub priority: u8,
    pub seed: u64,
}

/// The arrival trace of one live session — the replay recipe the
/// record→replay oracle (`sim::replay_check`) feeds back through the
/// virtual-time service.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct SessionRecording {
    /// Submissions in arrival order (index `i` is job `i + 1`).
    pub jobs: Vec<RecordedJob>,
}

impl SessionRecording {
    /// The replay arrival profile: the recorded offsets, verbatim.
    pub fn replay_profile(&self) -> ArrivalProfile {
        ArrivalProfile::Recorded {
            offsets_ns: self.jobs.iter().map(|j| j.offset_ns).collect(),
        }
    }

    /// Canonical text form: one line per submission, arrival order.
    pub fn render(&self) -> String {
        let mut out = String::new();
        for (i, j) in self.jobs.iter().enumerate() {
            out.push_str(&format!(
                "arrival {} offset_ns={} name={} tenant={} priority={} seed={} spec={}\n",
                i + 1,
                j.offset_ns,
                j.name,
                j.tenant,
                j.priority,
                j.seed,
                j.spec,
            ));
        }
        out
    }
}

/// Callbacks a live session fires as jobs move through the service —
/// the HTTP front door's state registry implements this to surface job
/// status without reaching into the service loop. `()` is the no-op
/// observer for tests.
pub trait LiveObserver: Send + Sync {
    /// `job` left the wait queue and started running.
    fn on_admitted(&self, _job: JobId) {}
    /// `job` finished; `ok` is the engine's success bit, `fingerprint`
    /// the bit-exact sink digest, `row` the formatted outcome row.
    fn on_completed(&self, _job: JobId, _ok: bool, _fingerprint: &[(TaskId, u64)], _row: &str) {}
    /// `job` was shed without ever running.
    fn on_shed(&self, _job: JobId, _reason: ShedReason) {}
}

impl LiveObserver for () {}

/// Service configuration: the shared-platform base config plus the
/// arrival/admission policy.
#[derive(Clone, Debug)]
pub struct ServiceConfig {
    /// Platform knobs, network model, fault profile — applied to the ONE
    /// shared substrate every admitted job runs over.
    pub base: SimConfig,
    /// Seed of the arrival schedule (independent of per-job seeds).
    pub arrival_seed: u64,
    pub profile: ArrivalProfile,
    pub admission: Admission,
    /// How many jobs may run concurrently (admission gate, not the
    /// platform's Lambda concurrency cap — that still applies below).
    pub max_concurrent_jobs: usize,
    /// Arrivals beyond this many *waiting* jobs are rejected outright
    /// (load shedding), not queued.
    pub queue_cap: usize,
    /// Byte budget for resident KV intermediates of **finished** jobs.
    /// Each completed job is retired ([`KvStore::retire`]); retired
    /// arenas then keep their data only while the bytes retained by
    /// finished jobs stay under this budget — beyond it the
    /// oldest-finished arenas are evicted deterministically. Running
    /// jobs' live intermediates never count against the budget (they
    /// cannot be evicted). `u64::MAX` (default) retains everything; `0`
    /// reclaims every job's intermediates at retirement.
    ///
    /// [`KvStore::retire`]: crate::kvstore::KvStore::retire
    pub kv_byte_budget: u64,
    /// Per-tenant dollar budget. Once a tenant's completed-job cost
    /// (accumulated from each [`JobOutcome::cost_usd`]) reaches it, that
    /// tenant's arriving *and queued* jobs are shed with
    /// [`ShedReason::Budget`]. Infinite by default.
    ///
    /// With a budget **refill** armed (both refill knobs below set), the
    /// semantics soften from shed to *pause*: over-budget tenants' jobs
    /// park in the wait queue instead of being shed, and resume when the
    /// next window boundary raises the effective budget.
    pub tenant_budget_usd: f64,
    /// Dollars added to every tenant's *effective* budget at each
    /// [`budget_refill_window`](Self::budget_refill_window) boundary:
    /// at elapsed time `t` the effective budget is
    /// `tenant_budget_usd + refill * floor(t / window)`. `0.0` (the
    /// default) disarms the refill and restores the hard shed-at-budget
    /// semantics bit-for-bit.
    pub budget_refill_usd_per_window: f64,
    /// Length of one refill window. Meaningless while the refill amount
    /// is `0.0`.
    pub budget_refill_window: Duration,
    /// Demote budget-evicted arenas to the cold spill tier instead of
    /// destroying them (late `get`s then pay the cold penalty rather
    /// than failing with `MissingObject`). Defaults from
    /// `base.spill.enabled` — off unless armed.
    pub spill_enabled: bool,
    /// Cold-tier request latency, ms (defaults from `base.spill`).
    pub spill_latency_ms: f64,
    /// Cold-tier storage price, $ per GB-second, billed into the tenant
    /// dollar ledger at end-of-run settlement (defaults from
    /// `base.spill`).
    pub spill_cost_gb_s: f64,
    /// Record per-task spans in every job (expensive; off by default).
    pub sampling: bool,
    /// Number of parallel simulation shards. `1` (the default) runs the
    /// classic single-executor service loop, bit-identical to every
    /// prior release. `> 1` shards the virtual clock: each job runs on
    /// one of N per-shard executors synchronized by conservative PDES
    /// (`rt::sharded`), and the configuration must be in the
    /// contention-free service regime [`JobService::run_sharded`]
    /// validates — every job admitted at arrival, unlimited KV/tenant
    /// budgets, benign shared fault streams, strictly positive substrate
    /// latency floors (the lookahead window).
    pub sim_shards: usize,
}

impl ServiceConfig {
    /// A deterministic-test service config over `base`.
    pub fn new(base: SimConfig, arrival_seed: u64) -> Self {
        let spill_enabled = base.spill.enabled;
        let spill_latency_ms = base.spill.latency_ms;
        let spill_cost_gb_s = base.spill.cost_gb_s;
        ServiceConfig {
            base,
            arrival_seed,
            profile: ArrivalProfile::Uniform { gap_ms: 50.0 },
            admission: Admission::Fifo,
            max_concurrent_jobs: 8,
            queue_cap: 64,
            kv_byte_budget: u64::MAX,
            tenant_budget_usd: f64::INFINITY,
            budget_refill_usd_per_window: 0.0,
            budget_refill_window: Duration::ZERO,
            spill_enabled,
            spill_latency_ms,
            spill_cost_gb_s,
            sampling: false,
            sim_shards: 1,
        }
    }

    pub fn with_profile(mut self, profile: ArrivalProfile) -> Self {
        self.profile = profile;
        self
    }

    pub fn with_admission(mut self, admission: Admission) -> Self {
        self.admission = admission;
        self
    }

    pub fn with_concurrency(mut self, max_concurrent_jobs: usize, queue_cap: usize) -> Self {
        self.max_concurrent_jobs = max_concurrent_jobs;
        self.queue_cap = queue_cap;
        self
    }

    /// Caps resident KV bytes of finished jobs (see `kv_byte_budget`).
    pub fn with_kv_budget(mut self, bytes: u64) -> Self {
        self.kv_byte_budget = bytes;
        self
    }

    /// Caps each tenant's accumulated dollar spend (see
    /// `tenant_budget_usd`).
    pub fn with_tenant_budget(mut self, usd: f64) -> Self {
        self.tenant_budget_usd = usd;
        self
    }

    /// Arms the time-windowed budget refill: `usd` dollars join every
    /// tenant's effective budget at each `window` boundary, and
    /// over-budget tenants' jobs **pause** in the queue instead of being
    /// shed (see `budget_refill_usd_per_window`).
    pub fn with_budget_refill(mut self, usd: f64, window: Duration) -> Self {
        self.budget_refill_usd_per_window = usd;
        self.budget_refill_window = window;
        self
    }

    /// Whether the time-windowed refill is armed (both knobs set): the
    /// pause-instead-of-shed budget regime.
    pub fn refill_active(&self) -> bool {
        self.budget_refill_usd_per_window > 0.0 && self.budget_refill_window > Duration::ZERO
    }

    /// Arms (or disarms) the cold spill tier for budget-evicted
    /// intermediates (see `spill_enabled`).
    pub fn with_spill(mut self, enabled: bool) -> Self {
        self.spill_enabled = enabled;
        self
    }

    /// Shards the virtual clock across `n` parallel executors (see
    /// `sim_shards`). `1` restores the serial path.
    pub fn with_shards(mut self, n: usize) -> Self {
        assert!(n >= 1, "need at least one simulation shard");
        self.sim_shards = n;
        self
    }

    /// The base config with the service's spill knobs folded in — what
    /// the shared platform is actually built from.
    fn effective_base(&self) -> SimConfig {
        let mut base = self.base.clone();
        base.spill.enabled = self.spill_enabled;
        base.spill.latency_ms = self.spill_latency_ms;
        base.spill.cost_gb_s = self.spill_cost_gb_s;
        base
    }
}

/// Dollar cost of one completed job under the platform's billing model
/// ([`Billing::from_faas`], the same construction the fleet cost uses):
/// per-invocation fee plus GB-seconds of billed time. `report.billed` is
/// the sum of already granularity-rounded per-invocation durations, so
/// this aggregate equals summing [`Billing::cost_usd`] per invocation.
pub fn job_cost_usd(cfg: &SimConfig, report: &JobReport) -> f64 {
    let billing = Billing::from_faas(&cfg.faas);
    report.lambdas_invoked as f64 * billing.per_invocation_usd
        + report.billed.as_secs_f64() * billing.memory_gb * billing.gb_second_usd
}

/// Weighted-DRR class weight for `tenant` under the
/// [`NetConfig::nic_drr_class_weights`](crate::core::NetConfig) table —
/// `1` (the plain quantum) when the tenant has no entry.
fn tenant_nic_weight(cfg: &SimConfig, tenant: u32) -> u64 {
    cfg.net
        .nic_drr_class_weights
        .iter()
        .find(|&&(t, _)| t == tenant)
        .map_or(1, |&(_, w)| w.max(1))
}

/// Everything the service records about one completed job.
pub struct JobOutcome {
    pub job: JobId,
    pub tenant: u32,
    pub name: String,
    /// Admission priority the job ran with.
    pub priority: u8,
    /// Dollar cost of this job (fed into the tenant budget ledger).
    pub cost_usd: f64,
    /// Offsets from service start (virtual time).
    pub submitted: Duration,
    pub started: Duration,
    pub finished: Duration,
    pub report: JobReport,
    /// Bit-exact sink-output digest (comparable against an isolated
    /// single-job run of the same seed — the tenancy-isolation oracle).
    pub fingerprint: Vec<(TaskId, u64)>,
    /// The job's metrics hub: per-job KV samples, and per-task spans when
    /// [`ServiceConfig::sampling`] is on (rendered into the service
    /// trace).
    pub metrics: Arc<crate::metrics::MetricsHub>,
    /// The job's KV arena for post-mortem forensics (None for serverful
    /// policies). After retirement the arena's storage may have been
    /// reclaimed by the byte-budget eviction policy — pre-retirement
    /// state is in `forensics`.
    pub kv: Option<Arc<JobArena>>,
    /// Forensic snapshot of the arena captured at job completion,
    /// **before** retirement/eviction. Captured only when eviction is
    /// possible (`kv_byte_budget < u64::MAX`) — under an unlimited
    /// budget the live arena in `kv` is never reclaimed, so the
    /// snapshot would duplicate it. None for serverful policies.
    pub forensics: Option<ArenaForensics>,
}

impl JobOutcome {
    /// Time spent waiting for admission.
    pub fn queue_delay(&self) -> Duration {
        self.started.saturating_sub(self.submitted)
    }

    /// End-to-end latency as the tenant sees it (submit -> finish).
    pub fn latency(&self) -> Duration {
        self.finished.saturating_sub(self.submitted)
    }

    /// One formatted row for service tables.
    pub fn row(&self) -> String {
        // Rendered first so the `{:<6}` width applies (JobId's Display
        // does not honor padding flags).
        let job = self.job.to_string();
        format!(
            "{:<6} t{:<2} p{:<2} {:<14} {:<22} sub={:>8.3}s wait={:>7.3}s lat={:>8.3}s tasks={:<6} lambdas={:<5} cold={:<4} billed={:.1}s cost=${:.5}{}",
            job,
            self.tenant,
            self.priority,
            self.name,
            self.report.platform,
            self.submitted.as_secs_f64(),
            self.queue_delay().as_secs_f64(),
            self.latency().as_secs_f64(),
            self.report.tasks_executed,
            self.report.lambdas_invoked,
            self.report.cold_starts,
            self.report.billed.as_secs_f64(),
            self.cost_usd,
            if self.report.is_ok() { "" } else { "  FAILED" },
        )
    }
}

/// The outcome of one service run: per-job outcomes plus fleet-level
/// aggregates over the shared platform.
pub struct ServiceReport {
    /// Completed jobs, sorted by job id (== arrival order).
    pub outcomes: Vec<JobOutcome>,
    /// Shed jobs (queue over cap, priority preemption, tenant budget),
    /// sorted by job id.
    pub rejected: Vec<Shed>,
    /// Service makespan: start of first arrival to last completion.
    pub makespan: Duration,
    /// Fleet-wide peak concurrent function executions.
    pub peak_concurrency: u64,
    /// Fleet-wide dollar cost.
    pub fleet_cost_usd: f64,
    /// Jobs whose retired KV arenas the byte-budget policy evicted, in
    /// eviction (oldest-finished-first) order.
    pub evicted: Vec<JobId>,
    /// Per-tenant accumulated dollar spend (job cost + cold-storage
    /// settlement), sorted by tenant.
    pub tenant_spend: Vec<(u32, f64)>,
    /// Payload bytes demoted to the cold spill tier over the run (zero
    /// with spill off or nothing evicted).
    pub spill_demoted_bytes: u64,
    /// Cold reads served by the spill tier / bytes they streamed.
    pub spill_reads: u64,
    pub spill_read_bytes: u64,
    /// Objects promoted back to the warm KV tier after repeated cold
    /// reads ([`SpillConfig::promote_after_reads`](crate::core::SpillConfig)
    /// — zero with promotion off).
    pub spill_promotions: u64,
    /// GB-seconds of cold storage settled over the run (all spill sets
    /// are purged at end of run, so this is the whole bill).
    pub spill_gb_seconds: f64,
    /// Dollars of that settlement (already folded into `tenant_spend`).
    pub spill_cost_usd: f64,
    /// End-of-run KV ledger: resident bytes still held by the cluster
    /// (retained finished intermediates; zero under a zero byte budget).
    pub resident_kv_bytes: u64,
    /// End-of-run broker namespaces (must be zero: every completed job is
    /// retired, and shed jobs never create one).
    pub pubsub_namespaces: usize,
    /// End-of-run arena registry size (retained finished arenas; zero
    /// under a zero byte budget).
    pub registered_arenas: usize,
    /// Same-instant cross-shard gate admissions broken by arrival order
    /// during a sharded run ([`ServiceConfig::sim_shards`] `> 1`) — the
    /// documented determinism soundness boundary of conservative PDES
    /// (`rt::sharded`). Always zero for serial runs; `sim::parallel_check`
    /// pins it at zero for the swept scenarios. Not part of the canonical
    /// trace (it describes the simulator, not the simulated fleet).
    pub tie_breaks: u64,
}

impl ServiceReport {
    pub fn completed(&self) -> usize {
        self.outcomes.len()
    }

    pub fn all_ok(&self) -> bool {
        self.outcomes.iter().all(|o| o.report.is_ok())
    }

    pub fn total_lambdas(&self) -> u64 {
        self.outcomes.iter().map(|o| o.report.lambdas_invoked).sum()
    }

    pub fn total_cold_starts(&self) -> u64 {
        self.outcomes.iter().map(|o| o.report.cold_starts).sum()
    }

    /// Fraction of invocations that cold-started, fleet-wide.
    pub fn cold_start_share(&self) -> f64 {
        let total = self.total_lambdas();
        if total == 0 {
            0.0
        } else {
            self.total_cold_starts() as f64 / total as f64
        }
    }

    pub fn total_billed(&self) -> Duration {
        self.outcomes.iter().map(|o| o.report.billed).sum()
    }

    /// Latency percentile over completed jobs (`q` in [0, 1]).
    pub fn latency_percentile(&self, q: f64) -> Duration {
        if self.outcomes.is_empty() {
            return Duration::ZERO;
        }
        let mut lats: Vec<Duration> = self.outcomes.iter().map(|o| o.latency()).collect();
        lats.sort_unstable();
        let idx = ((lats.len() - 1) as f64 * q.clamp(0.0, 1.0)).round() as usize;
        lats[idx]
    }

    /// Payload bytes the whole fleet moved over the network (sum of the
    /// per-job traffic ledgers) — the number locality-enhanced scheduling
    /// shrinks at service scale.
    pub fn total_net_bytes(&self) -> u64 {
        self.outcomes.iter().map(|o| o.report.net_bytes_moved).sum()
    }

    /// Fleet-wide crash-recovery activity (sum of the per-job recovery
    /// ledgers) — all-zero on fault-free service runs.
    pub fn total_recovery(&self) -> crate::metrics::RecoveryStats {
        let mut total = crate::metrics::RecoveryStats::default();
        for o in &self.outcomes {
            let r = &o.report.recovery;
            total.invoke_retries += r.invoke_retries;
            total.backoff_ns_slept += r.backoff_ns_slept;
            total.leases_expired += r.leases_expired;
            total.tasks_recomputed += r.tasks_recomputed;
            total.hedges_launched += r.hedges_launched;
            total.hedges_won += r.hedges_won;
        }
        total
    }

    /// Fleet summary row.
    pub fn fleet_row(&self) -> String {
        format!(
            "fleet: {} completed, {} rejected | makespan {:.3}s | p50 lat {:.3}s, p99 lat {:.3}s | lambdas={} cold_share={:.1}% | peak_conc={} | net_bytes={} | billed={:.1}s cost=${:.4}",
            self.completed(),
            self.rejected.len(),
            self.makespan.as_secs_f64(),
            self.latency_percentile(0.5).as_secs_f64(),
            self.latency_percentile(0.99).as_secs_f64(),
            self.total_lambdas(),
            self.cold_start_share() * 100.0,
            self.peak_concurrency,
            self.total_net_bytes(),
            self.total_billed().as_secs_f64(),
            self.fleet_cost_usd,
        )
    }

    /// Canonical text rendering of the whole service run — the replay
    /// artifact two runs of the same configuration must agree on
    /// byte-for-byte (the service-level determinism check).
    pub fn render_trace(&self) -> String {
        let mut out = String::with_capacity(128 + self.outcomes.len() * 160);
        out.push_str(&format!(
            "service completed={} rejected={} makespan_ns={} peak_conc={} lambdas={} cold={} net_bytes={}\n",
            self.completed(),
            self.rejected.len(),
            self.makespan.as_nanos(),
            self.peak_concurrency,
            self.total_lambdas(),
            self.total_cold_starts(),
            self.total_net_bytes(),
        ));
        for s in &self.rejected {
            out.push_str(&format!(
                "rejected {} name={} tenant={} priority={} reason={}\n",
                s.job, s.name, s.tenant, s.priority, s.reason
            ));
        }
        for job in &self.evicted {
            out.push_str(&format!("evicted {job}\n"));
        }
        for o in &self.outcomes {
            out.push_str(&format!(
                "outcome {} tenant={} name={} submitted_ns={} started_ns={} finished_ns={}\n",
                o.job,
                o.tenant,
                o.name,
                o.submitted.as_nanos(),
                o.started.as_nanos(),
                o.finished.as_nanos(),
            ));
            // With sampling on, the per-task spans of every job land in
            // the service trace too (empty slice otherwise).
            out.push_str(&crate::sim::trace::render_trace(
                &o.report,
                &o.metrics.task_spans(),
            ));
        }
        for (tenant, usd) in &self.tenant_spend {
            out.push_str(&format!("tenant t{tenant} spent_usd={usd:.9}\n"));
        }
        // Emitted only when the tier saw traffic, so spill-off runs (and
        // armed-but-inert runs) stay byte-identical to the pre-spill
        // trace format.
        if self.spill_demoted_bytes > 0 || self.spill_reads > 0 {
            out.push_str(&format!(
                "spill demoted_bytes={} reads={} read_bytes={} gb_seconds={:.9} cost_usd={:.12}",
                self.spill_demoted_bytes,
                self.spill_reads,
                self.spill_read_bytes,
                self.spill_gb_seconds,
                self.spill_cost_usd,
            ));
            // Promotion suffix only when promotions happened, so runs
            // with the knob off render the exact pre-promotion format.
            if self.spill_promotions > 0 {
                out.push_str(&format!(" promotions={}", self.spill_promotions));
            }
            out.push('\n');
        }
        // Same activity gate for the fleet recovery ledger: fault-free
        // (and recovery-off) service runs render the pre-recovery format.
        let rec = self.total_recovery();
        if rec.any() {
            out.push_str(&format!(
                "recovery retries={} backoff_ns={} leases_expired={} recomputed={} \
                 hedges_launched={} hedges_won={}\n",
                rec.invoke_retries,
                rec.backoff_ns_slept,
                rec.leases_expired,
                rec.tasks_recomputed,
                rec.hedges_launched,
                rec.hedges_won,
            ));
        }
        out.push_str(&format!(
            "substrate resident_bytes={} namespaces={} arenas={}\n",
            self.resident_kv_bytes, self.pubsub_namespaces, self.registered_arenas
        ));
        out
    }
}

/// The job service itself: owns the admission policy and drives arrivals,
/// admission, and job execution over one [`SharedPlatform`].
pub struct JobService {
    cfg: ServiceConfig,
}

impl JobService {
    pub fn new(cfg: ServiceConfig) -> Self {
        assert!(cfg.max_concurrent_jobs >= 1, "need at least one job slot");
        JobService { cfg }
    }

    pub fn cfg(&self) -> &ServiceConfig {
        &self.cfg
    }

    /// Position within `queue` of the next job to admit, per the
    /// admission policy. Tenants in `parked` (over their effective
    /// budget under an armed refill — always empty otherwise) are
    /// skipped: their jobs wait for the next refill window. `None` iff
    /// no admittable job is queued.
    fn pick(
        &self,
        queue: &VecDeque<usize>,
        requests: &[Option<JobRequest>],
        tenant_admitted: &HashMap<u32, usize>,
        parked: &HashSet<u32>,
    ) -> Option<usize> {
        if queue.is_empty() {
            return None;
        }
        let tenant_of =
            |idx: usize| -> u32 { requests[idx].as_ref().expect("queued twice").tenant };
        match self.cfg.admission {
            Admission::Fifo => queue
                .iter()
                .position(|&idx| !parked.contains(&tenant_of(idx))),
            Admission::Fair => {
                // Least-admitted tenant first; arrival order breaks ties.
                let mut best: Option<usize> = None;
                let mut best_load = usize::MAX;
                for (pos, &idx) in queue.iter().enumerate() {
                    let tenant = tenant_of(idx);
                    if parked.contains(&tenant) {
                        continue;
                    }
                    let load = *tenant_admitted.get(&tenant).unwrap_or(&0);
                    if load < best_load {
                        best_load = load;
                        best = Some(pos);
                    }
                }
                best
            }
            Admission::Priority => {
                // Highest priority first; arrival order breaks ties.
                let mut best: Option<usize> = None;
                let mut best_prio = 0u8;
                for (pos, &idx) in queue.iter().enumerate() {
                    if parked.contains(&tenant_of(idx)) {
                        continue;
                    }
                    let prio = requests[idx].as_ref().expect("queued twice").priority;
                    if best.is_none() || prio > best_prio {
                        best_prio = prio;
                        best = Some(pos);
                    }
                }
                best
            }
        }
    }

    /// Runs the service over `jobs` (arrival order = vector order) inside
    /// the **current** virtual-time executor. Use [`run_service`] from
    /// synchronous code.
    pub async fn run(&self, jobs: Vec<JobRequest>) -> ServiceReport {
        let n = jobs.len();
        let base = self.cfg.effective_base();
        let platform = SharedPlatform::new(&base);
        let arrivals = self.cfg.profile.arrival_offsets(n, self.cfg.arrival_seed);
        let t0 = clock::now();

        let (done_tx, mut done_rx) = mpsc::unbounded::<JobOutcome>();
        let mut requests: Vec<Option<JobRequest>> = jobs.into_iter().map(Some).collect();
        let mut queue: VecDeque<usize> = VecDeque::new();
        let mut tenant_admitted: HashMap<u32, usize> = HashMap::new();
        let mut tenant_spent: HashMap<u32, f64> = HashMap::new();
        let mut next_arrival = 0usize;
        let mut running = 0usize;
        let mut outcomes: Vec<JobOutcome> = Vec::with_capacity(n);
        let mut rejected: Vec<Shed> = Vec::new();
        let mut evicted: Vec<JobId> = Vec::new();

        // Sheds `idx` (a not-yet-admitted job) for `reason`.
        let shed = |rejected: &mut Vec<Shed>, requests: &mut [Option<JobRequest>], idx: usize, reason: ShedReason| {
            let req = requests[idx].take().expect("shed twice");
            rejected.push(Shed {
                job: JobId(idx as u64 + 1),
                name: req.name,
                tenant: req.tenant,
                priority: req.priority,
                reason,
            });
        };
        // With the refill armed, a tenant's effective budget grows by
        // `refill` dollars at every window boundary; without it, the
        // budget is the flat configured cap (identical to every prior
        // release).
        let refill = self.cfg.refill_active();
        let budget_at = |elapsed: Duration| -> f64 {
            if refill {
                let windows =
                    (elapsed.as_nanos() / self.cfg.budget_refill_window.as_nanos()) as f64;
                self.cfg.tenant_budget_usd + self.cfg.budget_refill_usd_per_window * windows
            } else {
                self.cfg.tenant_budget_usd
            }
        };
        let over_budget = |spent: &HashMap<u32, f64>, tenant: u32, elapsed: Duration| {
            *spent.get(&tenant).unwrap_or(&0.0) >= budget_at(elapsed)
        };

        while outcomes.len() + rejected.len() < n {
            // Tenants paused by the refill regime: over their effective
            // budget *right now*, jobs parked until the next window.
            // Always empty with the refill off, so `pick` degenerates to
            // its classic policies.
            let parked: HashSet<u32> = if refill {
                let elapsed = clock::now() - t0;
                queue
                    .iter()
                    .map(|&idx| requests[idx].as_ref().expect("queued twice").tenant)
                    .filter(|&t| over_budget(&tenant_spent, t, elapsed))
                    .collect()
            } else {
                HashSet::new()
            };
            // Admit while job slots are free.
            while running < self.cfg.max_concurrent_jobs {
                let Some(pos) = self.pick(&queue, &requests, &tenant_admitted, &parked) else {
                    break;
                };
                let idx = queue.remove(pos).expect("picked position exists");
                let req = requests[idx].take().expect("admitted twice");
                *tenant_admitted.entry(req.tenant).or_insert(0) += 1;
                running += 1;

                let job = JobId(idx as u64 + 1);
                // The tenant's DRR class weight applies to every NIC
                // transfer the job issues; `KvStore::retire` clears the
                // entry with the job. With no weight table (the default)
                // nothing is registered and the NIC is bit-identical to
                // the unweighted engine.
                let weight = tenant_nic_weight(&base, req.tenant);
                if weight != 1 {
                    platform.kv.set_job_nic_weight(job, weight);
                }
                let submitted = arrivals[idx];
                let started = clock::now() - t0;
                let mut job_cfg = base.clone();
                job_cfg.seed = req.seed;
                let platform = Arc::clone(&platform);
                let tx = done_tx.clone();
                let sampling = self.cfg.sampling;
                // Snapshot arenas only when the byte budget can actually
                // evict them; with an unlimited budget the live arena
                // survives and a snapshot would be O(objects) of pure
                // overhead on every completion.
                let snapshot = self.cfg.kv_byte_budget < u64::MAX;
                crate::rt::spawn(async move {
                    let mut driver = EngineDriver::with_policy(job_cfg, req.policy)
                        .on_platform(platform)
                        .for_job(job)
                        .for_tenant(req.tenant);
                    if sampling {
                        driver = driver.with_sampling();
                    }
                    let run = driver.run_forensic(&req.dag).await;
                    let fingerprint = crate::sim::harness::fingerprint_outputs(&run.outputs);
                    // Snapshot the arena before the service retires the
                    // job (eviction may reclaim the live storage).
                    let forensics = if snapshot {
                        run.kv.as_ref().map(|kv| kv.forensics())
                    } else {
                        None
                    };
                    let _ = tx.send(JobOutcome {
                        job,
                        tenant: req.tenant,
                        name: req.name,
                        priority: req.priority,
                        cost_usd: 0.0, // filled by the service loop
                        submitted,
                        started,
                        finished: clock::now() - t0,
                        report: run.report,
                        fingerprint,
                        metrics: run.metrics,
                        kv: run.kv,
                        forensics,
                    });
                });
            }

            // Absorb the next due arrival — ONE at a time, interleaved
            // with admission, so a burst fills free job slots before the
            // queue cap sheds anyone. Shedding only applies to jobs that
            // would actually have to *wait*: with a free job slot the
            // arrival is admitted on the next pass even at queue_cap 0
            // (the admit step above drains the queue whenever slots are
            // free, so a free slot implies the queue is empty here).
            if next_arrival < n && clock::now() - t0 >= arrivals[next_arrival] {
                let idx = next_arrival;
                next_arrival += 1;
                let (tenant, priority) = {
                    let req = requests[idx].as_ref().expect("arrived twice");
                    (req.tenant, req.priority)
                };
                if !refill && over_budget(&tenant_spent, tenant, clock::now() - t0) {
                    // The tenant's dollar budget is exhausted: reject at
                    // the door, before any substrate is touched. With
                    // the refill armed the job queues instead — it will
                    // park until a window boundary refills the tenant.
                    shed(&mut rejected, &mut requests, idx, ShedReason::Budget);
                } else if running >= self.cfg.max_concurrent_jobs
                    && queue.len() >= self.cfg.queue_cap
                {
                    // Queue full. Under priority admission a strictly
                    // higher-priority arrival preempts the lowest-priority
                    // *queued* job (running jobs always finish); among
                    // equal-priority victims the latest arrival goes, so
                    // earlier arrivals keep their place.
                    let victim = if self.cfg.admission == Admission::Priority {
                        let mut victim: Option<(usize, u8)> = None;
                        for (pos, &qidx) in queue.iter().enumerate() {
                            let p = requests[qidx].as_ref().expect("queued twice").priority;
                            if victim.is_none_or(|(_, vp)| p <= vp) {
                                victim = Some((pos, p));
                            }
                        }
                        victim.filter(|&(_, vp)| vp < priority).map(|(pos, _)| pos)
                    } else {
                        None
                    };
                    match victim {
                        Some(pos) => {
                            let vidx = queue.remove(pos).expect("victim position exists");
                            shed(&mut rejected, &mut requests, vidx, ShedReason::Preempted);
                            queue.push_back(idx);
                        }
                        None => shed(&mut rejected, &mut requests, idx, ShedReason::QueueFull),
                    }
                } else {
                    queue.push_back(idx);
                }
                continue; // try to admit it right away
            }

            // Wait for the next event: a completion, the next arrival,
            // or — with jobs parked under the refill regime — the next
            // refill-window boundary (which may unpark a tenant).
            let next_wake: Option<Duration> = {
                let arrival = (next_arrival < n).then(|| arrivals[next_arrival]);
                let boundary = if refill && !queue.is_empty() {
                    let w_ns = self.cfg.budget_refill_window.as_nanos() as u64;
                    let elapsed_ns = (clock::now() - t0).as_nanos() as u64;
                    Some(Duration::from_nanos(
                        (elapsed_ns / w_ns + 1).saturating_mul(w_ns),
                    ))
                } else {
                    None
                };
                match (arrival, boundary) {
                    (Some(a), Some(b)) => Some(a.min(b)),
                    (a, b) => a.or(b),
                }
            };
            let completed: Option<JobOutcome> = if let Some(at) = next_wake {
                let wait = at.saturating_sub(clock::now() - t0);
                match crate::rt::timeout(wait, done_rx.recv()).await {
                    Ok(Some(outcome)) => Some(outcome),
                    Ok(None) => unreachable!("service holds a live sender"),
                    Err(_) => None, // arrival or refill due — loop top handles it
                }
            } else if running > 0 {
                match done_rx.recv().await {
                    Some(outcome) => Some(outcome),
                    None => unreachable!("service holds a live sender"),
                }
            } else {
                // No arrival pending, nothing running: every job is
                // accounted for (the budget sweep below clears the queue
                // the moment a tenant goes over), so the loop condition
                // is about to end the service.
                debug_assert!(queue.is_empty());
                None
            };

            if let Some(mut outcome) = completed {
                running -= 1;
                // Feed the tenant ledger from the job's billed cost.
                let cost = job_cost_usd(&self.cfg.base, &outcome.report);
                outcome.cost_usd = cost;
                *tenant_spent.entry(outcome.tenant).or_insert(0.0) += cost;
                // Retire the job's substrate: stamp the arena finished,
                // tear down its channel namespace, and evict
                // oldest-finished arenas beyond the byte budget.
                platform.kv.retire(outcome.job);
                evicted.extend(platform.kv.enforce_kv_budget(self.cfg.kv_byte_budget));
                // Budget sweep: tenants only cross their budget at a
                // completion, so shedding their queued jobs here keeps
                // the queue free of unadmittable entries. Skipped under
                // the refill regime — over-budget jobs park instead.
                if !refill && over_budget(&tenant_spent, outcome.tenant, clock::now() - t0) {
                    let mut pos = 0;
                    while pos < queue.len() {
                        let qidx = queue[pos];
                        if requests[qidx].as_ref().expect("queued twice").tenant
                            == outcome.tenant
                        {
                            queue.remove(pos);
                            shed(&mut rejected, &mut requests, qidx, ShedReason::Budget);
                        } else {
                            pos += 1;
                        }
                    }
                }
                outcomes.push(outcome);
            }
        }

        let makespan = clock::now() - t0;
        outcomes.sort_by_key(|o| o.job);
        rejected.sort_by_key(|r| r.job);
        // End-of-run spill settlement: purge every remaining cold set
        // (deterministic uid order) and bill each job's storage-seconds
        // to its tenant — the storage half of the pay-per-use ledger.
        // After this the tier's live accrual is zero ("billing closes to
        // zero"); with spill off every number here is zero and nothing
        // changes.
        let spill = platform.kv.spill();
        let job_tenant: HashMap<u64, u32> = outcomes.iter().map(|o| (o.job.0, o.tenant)).collect();
        for bill in spill.purge_all(clock::now()) {
            if let Some(&tenant) = job_tenant.get(&bill.job) {
                *tenant_spent.entry(tenant).or_insert(0.0) +=
                    bill.gb_seconds * base.spill.cost_gb_s;
            }
        }
        let spill_gb_seconds = spill.settled_gb_seconds();
        let mut tenant_spend: Vec<(u32, f64)> = tenant_spent.into_iter().collect();
        tenant_spend.sort_by_key(|&(t, _)| t);
        ServiceReport {
            outcomes,
            rejected,
            makespan,
            peak_concurrency: platform.peak_concurrency(),
            fleet_cost_usd: platform.total_cost_usd(),
            evicted,
            tenant_spend,
            spill_demoted_bytes: spill.demoted_bytes(),
            spill_reads: spill.reads(),
            spill_read_bytes: spill.read_bytes(),
            spill_promotions: spill.promotions(),
            spill_gb_seconds,
            spill_cost_usd: spill_gb_seconds * base.spill.cost_gb_s,
            resident_kv_bytes: platform.kv.resident_kv_bytes(),
            pubsub_namespaces: platform.kv.pubsub_namespace_count(),
            registered_arenas: platform.kv.registered_arena_count(),
            tie_breaks: 0,
        }
    }

    /// Runs the service **live**: submissions stream in over `rx` from
    /// outside the executor (the HTTP front door's accept threads) at
    /// whatever wall-clock moments tenants choose, until every sender
    /// is dropped. Meant for `Mode::Real` executors ([`crate::rt::block_on`]
    /// over [`WallTime`](crate::rt::WallTime)); runs under virtual time
    /// too, which is how the equivalence tests drive it.
    ///
    /// Every submission is recorded — arrival offset, raw spec, tenant,
    /// priority, seed — into the returned [`SessionRecording`]. Feeding
    /// that recording back through [`run`](Self::run) with
    /// [`ArrivalProfile::Recorded`] replays the session in virtual
    /// time; `sim::replay_check` pins per-job fingerprints and shed
    /// decisions equal between the two.
    pub async fn run_live(
        &self,
        mut rx: mpsc::Receiver<LiveSubmission>,
        observer: Arc<dyn LiveObserver>,
    ) -> (ServiceReport, SessionRecording) {
        enum LiveEvent {
            Submit(LiveSubmission),
            Done(JobOutcome),
            IngestClosed,
        }
        let base = self.cfg.effective_base();
        let platform = SharedPlatform::new(&base);
        let t0 = clock::now();

        // Merge external submissions and in-executor completions into
        // one event stream (the runtime has no select). The pump task
        // holds an ExternalGuard for as long as the ingest side is
        // open, so an otherwise-idle executor parks for the HTTP
        // threads instead of declaring deadlock.
        let (evt_tx, mut evt_rx) = mpsc::unbounded::<LiveEvent>();
        let pump_tx = evt_tx.clone();
        crate::rt::spawn(async move {
            let _guard = crate::rt::ExternalGuard::register();
            while let Some(sub) = rx.recv().await {
                let _ = pump_tx.send(LiveEvent::Submit(sub));
            }
            let _ = pump_tx.send(LiveEvent::IngestClosed);
        });

        let mut requests: Vec<Option<JobRequest>> = Vec::new();
        let mut arrivals: Vec<Duration> = Vec::new();
        let mut recording = SessionRecording::default();
        let mut queue: VecDeque<usize> = VecDeque::new();
        let mut tenant_admitted: HashMap<u32, usize> = HashMap::new();
        let mut tenant_spent: HashMap<u32, f64> = HashMap::new();
        let mut running = 0usize;
        let mut outcomes: Vec<JobOutcome> = Vec::new();
        let mut rejected: Vec<Shed> = Vec::new();
        let mut evicted: Vec<JobId> = Vec::new();
        let mut ingest_open = true;

        let shed = |rejected: &mut Vec<Shed>,
                    requests: &mut [Option<JobRequest>],
                    idx: usize,
                    reason: ShedReason| {
            let req = requests[idx].take().expect("shed twice");
            rejected.push(Shed {
                job: JobId(idx as u64 + 1),
                name: req.name,
                tenant: req.tenant,
                priority: req.priority,
                reason,
            });
        };
        let refill = self.cfg.refill_active();
        let budget_at = |elapsed: Duration| -> f64 {
            if refill {
                let windows =
                    (elapsed.as_nanos() / self.cfg.budget_refill_window.as_nanos()) as f64;
                self.cfg.tenant_budget_usd + self.cfg.budget_refill_usd_per_window * windows
            } else {
                self.cfg.tenant_budget_usd
            }
        };
        let over_budget = |spent: &HashMap<u32, f64>, tenant: u32, elapsed: Duration| {
            *spent.get(&tenant).unwrap_or(&0.0) >= budget_at(elapsed)
        };

        while ingest_open || running > 0 || !queue.is_empty() {
            // Admit while job slots are free — the serial admit body.
            let parked: HashSet<u32> = if refill {
                let elapsed = clock::now() - t0;
                queue
                    .iter()
                    .map(|&idx| requests[idx].as_ref().expect("queued twice").tenant)
                    .filter(|&t| over_budget(&tenant_spent, t, elapsed))
                    .collect()
            } else {
                HashSet::new()
            };
            while running < self.cfg.max_concurrent_jobs {
                let Some(pos) = self.pick(&queue, &requests, &tenant_admitted, &parked) else {
                    break;
                };
                let idx = queue.remove(pos).expect("picked position exists");
                let req = requests[idx].take().expect("admitted twice");
                *tenant_admitted.entry(req.tenant).or_insert(0) += 1;
                running += 1;

                let job = JobId(idx as u64 + 1);
                let weight = tenant_nic_weight(&base, req.tenant);
                if weight != 1 {
                    platform.kv.set_job_nic_weight(job, weight);
                }
                observer.on_admitted(job);
                let submitted = arrivals[idx];
                let started = clock::now() - t0;
                let mut job_cfg = base.clone();
                job_cfg.seed = req.seed;
                let platform = Arc::clone(&platform);
                let tx = evt_tx.clone();
                let sampling = self.cfg.sampling;
                let snapshot = self.cfg.kv_byte_budget < u64::MAX;
                crate::rt::spawn(async move {
                    let mut driver = EngineDriver::with_policy(job_cfg, req.policy)
                        .on_platform(platform)
                        .for_job(job)
                        .for_tenant(req.tenant);
                    if sampling {
                        driver = driver.with_sampling();
                    }
                    let run = driver.run_forensic(&req.dag).await;
                    let fingerprint = crate::sim::harness::fingerprint_outputs(&run.outputs);
                    let forensics = if snapshot {
                        run.kv.as_ref().map(|kv| kv.forensics())
                    } else {
                        None
                    };
                    let _ = tx.send(LiveEvent::Done(JobOutcome {
                        job,
                        tenant: req.tenant,
                        name: req.name,
                        priority: req.priority,
                        cost_usd: 0.0, // filled by the completion fold
                        submitted,
                        started,
                        finished: clock::now() - t0,
                        report: run.report,
                        fingerprint,
                        metrics: run.metrics,
                        kv: run.kv,
                        forensics,
                    }));
                });
            }

            // Block for the next event. With jobs parked under the
            // refill regime, also wake at the next window boundary.
            let event = if refill && !queue.is_empty() {
                let w_ns = self.cfg.budget_refill_window.as_nanos() as u64;
                let elapsed_ns = (clock::now() - t0).as_nanos() as u64;
                let at = Duration::from_nanos((elapsed_ns / w_ns + 1).saturating_mul(w_ns));
                let wait = at.saturating_sub(clock::now() - t0);
                match crate::rt::timeout(wait, evt_rx.recv()).await {
                    Ok(ev) => ev,
                    Err(_) => continue, // boundary reached — re-admit
                }
            } else {
                evt_rx.recv().await
            };
            match event {
                Some(LiveEvent::Submit(sub)) => {
                    let idx = requests.len();
                    let offset = clock::now() - t0;
                    arrivals.push(offset);
                    recording.jobs.push(RecordedJob {
                        offset_ns: offset.as_nanos() as u64,
                        spec: sub.spec,
                        name: sub.req.name.clone(),
                        tenant: sub.req.tenant,
                        priority: sub.req.priority,
                        seed: sub.req.seed,
                    });
                    let (tenant, priority) = (sub.req.tenant, sub.req.priority);
                    requests.push(Some(sub.req));
                    // The serial door decision, verbatim.
                    if !refill && over_budget(&tenant_spent, tenant, offset) {
                        shed(&mut rejected, &mut requests, idx, ShedReason::Budget);
                        observer.on_shed(JobId(idx as u64 + 1), ShedReason::Budget);
                    } else if running >= self.cfg.max_concurrent_jobs
                        && queue.len() >= self.cfg.queue_cap
                    {
                        let victim = if self.cfg.admission == Admission::Priority {
                            let mut victim: Option<(usize, u8)> = None;
                            for (pos, &qidx) in queue.iter().enumerate() {
                                let p =
                                    requests[qidx].as_ref().expect("queued twice").priority;
                                if victim.is_none_or(|(_, vp)| p <= vp) {
                                    victim = Some((pos, p));
                                }
                            }
                            victim.filter(|&(_, vp)| vp < priority).map(|(pos, _)| pos)
                        } else {
                            None
                        };
                        match victim {
                            Some(pos) => {
                                let vidx = queue.remove(pos).expect("victim position exists");
                                shed(&mut rejected, &mut requests, vidx, ShedReason::Preempted);
                                observer.on_shed(JobId(vidx as u64 + 1), ShedReason::Preempted);
                                queue.push_back(idx);
                            }
                            None => {
                                shed(&mut rejected, &mut requests, idx, ShedReason::QueueFull);
                                observer.on_shed(JobId(idx as u64 + 1), ShedReason::QueueFull);
                            }
                        }
                    } else {
                        queue.push_back(idx);
                    }
                }
                Some(LiveEvent::Done(mut outcome)) => {
                    running -= 1;
                    let cost = job_cost_usd(&self.cfg.base, &outcome.report);
                    outcome.cost_usd = cost;
                    *tenant_spent.entry(outcome.tenant).or_insert(0.0) += cost;
                    platform.kv.retire(outcome.job);
                    evicted.extend(platform.kv.enforce_kv_budget(self.cfg.kv_byte_budget));
                    if !refill
                        && over_budget(&tenant_spent, outcome.tenant, clock::now() - t0)
                    {
                        let mut pos = 0;
                        while pos < queue.len() {
                            let qidx = queue[pos];
                            if requests[qidx].as_ref().expect("queued twice").tenant
                                == outcome.tenant
                            {
                                queue.remove(pos);
                                shed(&mut rejected, &mut requests, qidx, ShedReason::Budget);
                                observer.on_shed(JobId(qidx as u64 + 1), ShedReason::Budget);
                            } else {
                                pos += 1;
                            }
                        }
                    }
                    observer.on_completed(
                        outcome.job,
                        outcome.report.is_ok(),
                        &outcome.fingerprint,
                        &outcome.row(),
                    );
                    outcomes.push(outcome);
                }
                Some(LiveEvent::IngestClosed) => ingest_open = false,
                None => unreachable!("service holds a live event sender"),
            }
        }

        // The serial epilogue, verbatim.
        let makespan = clock::now() - t0;
        outcomes.sort_by_key(|o| o.job);
        rejected.sort_by_key(|r| r.job);
        let spill = platform.kv.spill();
        let job_tenant: HashMap<u64, u32> =
            outcomes.iter().map(|o| (o.job.0, o.tenant)).collect();
        for bill in spill.purge_all(clock::now()) {
            if let Some(&tenant) = job_tenant.get(&bill.job) {
                *tenant_spent.entry(tenant).or_insert(0.0) +=
                    bill.gb_seconds * base.spill.cost_gb_s;
            }
        }
        let spill_gb_seconds = spill.settled_gb_seconds();
        let mut tenant_spend: Vec<(u32, f64)> = tenant_spent.into_iter().collect();
        tenant_spend.sort_by_key(|&(t, _)| t);
        let report = ServiceReport {
            outcomes,
            rejected,
            makespan,
            peak_concurrency: platform.peak_concurrency(),
            fleet_cost_usd: platform.total_cost_usd(),
            evicted,
            tenant_spend,
            spill_demoted_bytes: spill.demoted_bytes(),
            spill_reads: spill.reads(),
            spill_read_bytes: spill.read_bytes(),
            spill_promotions: spill.promotions(),
            spill_gb_seconds,
            spill_cost_usd: spill_gb_seconds * base.spill.cost_gb_s,
            resident_kv_bytes: platform.kv.resident_kv_bytes(),
            pubsub_namespaces: platform.kv.pubsub_namespace_count(),
            registered_arenas: platform.kv.registered_arena_count(),
            tie_breaks: 0,
        };
        (report, recording)
    }

    /// Panics unless the configuration is in the contention-free regime
    /// the sharded path is equivalence-checked for. Each rejected knob is
    /// a *global serialization point*: its semantics depend on the total
    /// order of events across jobs, which only the serial loop (or a
    /// far heavier synchronization protocol) provides.
    fn validate_sharded(&self, n_jobs: usize) {
        let b = &self.cfg.base;
        assert!(
            self.cfg.max_concurrent_jobs >= n_jobs,
            "sim_shards > 1 requires contention-free admission: \
             max_concurrent_jobs ({}) must cover all {} jobs (queueing \
             couples every job's start time to global completion order)",
            self.cfg.max_concurrent_jobs,
            n_jobs,
        );
        assert_eq!(
            self.cfg.kv_byte_budget,
            u64::MAX,
            "sim_shards > 1 requires an unlimited kv_byte_budget \
             (mid-run eviction depends on global completion order)"
        );
        assert!(
            self.cfg.tenant_budget_usd.is_infinite(),
            "sim_shards > 1 requires an infinite tenant_budget_usd \
             (budget shedding depends on global completion order)"
        );
        assert!(
            !self.cfg.refill_active(),
            "sim_shards > 1 requires the budget refill to be disarmed \
             (windowed pause/resume admission depends on global \
             completion order)"
        );
        assert!(
            b.faults.crash_prob == 0.0 && b.faults.cold_start_spread == 0.0 && !b.faults.lethal,
            "sim_shards > 1 requires benign shared fault streams \
             (crash_prob == 0, cold_start_spread == 0, not lethal): the \
             platform fault RNG is a single sequence whose draw order \
             would depend on shard scheduling, not virtual time"
        );
        assert!(
            b.net.kv_latency_us > 0.0
                && b.net.pubsub_latency_us > 0.0
                && b.faas.invoke_latency_ms > 0.0,
            "sim_shards > 1 requires strictly positive substrate latency \
             floors (kv_latency_us, pubsub_latency_us, invoke_latency_ms): \
             they are the conservative lookahead window that keeps the \
             fleet's low-water mark ratcheting forward"
        );
    }

    /// Runs the service with the virtual clock sharded across
    /// [`ServiceConfig::sim_shards`] per-shard executors, one OS thread
    /// each, synchronized by conservative PDES (`rt::sharded`). The
    /// synchronous entry point [`run_service`] dispatches here when
    /// `sim_shards > 1`.
    ///
    /// Jobs partition whole-job-per-shard by arrival index
    /// (`idx % sim_shards`); each shard spawns its jobs at their arrival
    /// offsets and runs the exact serial job body. The completion fold
    /// the serial loop performs online (cost → tenant ledger → retire)
    /// replays post-hoc in canonical `(finished, job)` order, which is
    /// the order the serial loop drains completions in — exact finish-time
    /// ties between *different* jobs are broken by job id, the one
    /// documented divergence boundary (`ShardStats::tie_breaks` counts
    /// the analogous gate ties; `sim::parallel_check` pins both stay
    /// benign for the swept scenarios).
    ///
    /// For every seed the returned report renders a canonical trace
    /// byte-identical to the serial path's.
    pub fn run_sharded(&self, jobs: Vec<JobRequest>) -> ServiceReport {
        let shards = self.cfg.sim_shards.max(1);
        let n = jobs.len();
        self.validate_sharded(n);
        let base = self.cfg.effective_base();
        let platform = SharedPlatform::new(&base);
        let arrivals = self.cfg.profile.arrival_offsets(n, self.cfg.arrival_seed);

        // Whole-job-per-shard partition. DRR class weights register up
        // front (the serial path resolves them at admission; a job's NIC
        // transfers only start after its arrival, so pre-registering is
        // behavior-equivalent and needs no gate).
        let mut per_shard: Vec<Vec<(usize, Duration, JobRequest)>> =
            (0..shards).map(|_| Vec::new()).collect();
        for (idx, req) in jobs.into_iter().enumerate() {
            let weight = tenant_nic_weight(&base, req.tenant);
            if weight != 1 {
                platform.kv.set_job_nic_weight(JobId(idx as u64 + 1), weight);
            }
            per_shard[idx % shards].push((idx, arrivals[idx], req));
        }

        let sampling = self.cfg.sampling;
        let mains: Vec<_> = per_shard
            .into_iter()
            .map(|owned| {
                let base = base.clone();
                let platform = Arc::clone(&platform);
                move || shard_main(base, platform, owned, sampling)
            })
            .collect();
        let (shard_outcomes, stats) = crate::rt::run_sharded_stats(mains);

        // Post-hoc canonical completion fold, replaying the serial
        // loop's per-completion bookkeeping in its drain order. Under
        // the validated regime retirement has no cross-job effect while
        // jobs run (nothing evicts, namespaces are job-scoped), so
        // deferring it past the fleet is invisible to the jobs.
        let mut outcomes: Vec<JobOutcome> = shard_outcomes.into_iter().flatten().collect();
        outcomes.sort_by_key(|o| (o.finished, o.job));
        let makespan = outcomes
            .iter()
            .map(|o| o.finished)
            .max()
            .unwrap_or(Duration::ZERO);
        let mut tenant_spent: HashMap<u32, f64> = HashMap::new();
        let mut evicted: Vec<JobId> = Vec::new();
        for o in &mut outcomes {
            let cost = job_cost_usd(&self.cfg.base, &o.report);
            o.cost_usd = cost;
            *tenant_spent.entry(o.tenant).or_insert(0.0) += cost;
            platform.kv.retire(o.job);
            evicted.extend(platform.kv.enforce_kv_budget(self.cfg.kv_byte_budget));
        }
        outcomes.sort_by_key(|o| o.job);

        // End-of-run spill settlement at the makespan instant, exactly
        // where the serial loop's clock rests when it settles. Inert
        // under the validated regime (nothing ever demotes), kept for
        // structural parity with the serial epilogue.
        let spill = platform.kv.spill();
        let job_tenant: HashMap<u64, u32> =
            outcomes.iter().map(|o| (o.job.0, o.tenant)).collect();
        for bill in spill.purge_all(crate::rt::SimInstant::default() + makespan) {
            if let Some(&tenant) = job_tenant.get(&bill.job) {
                *tenant_spent.entry(tenant).or_insert(0.0) +=
                    bill.gb_seconds * base.spill.cost_gb_s;
            }
        }
        let spill_gb_seconds = spill.settled_gb_seconds();
        let mut tenant_spend: Vec<(u32, f64)> = tenant_spent.into_iter().collect();
        tenant_spend.sort_by_key(|&(t, _)| t);
        ServiceReport {
            outcomes,
            rejected: Vec::new(),
            makespan,
            peak_concurrency: platform.peak_concurrency(),
            fleet_cost_usd: platform.total_cost_usd(),
            evicted,
            tenant_spend,
            spill_demoted_bytes: spill.demoted_bytes(),
            spill_reads: spill.reads(),
            spill_read_bytes: spill.read_bytes(),
            spill_promotions: spill.promotions(),
            spill_gb_seconds,
            spill_cost_usd: spill_gb_seconds * base.spill.cost_gb_s,
            resident_kv_bytes: platform.kv.resident_kv_bytes(),
            pubsub_namespaces: platform.kv.pubsub_namespace_count(),
            registered_arenas: platform.kv.registered_arena_count(),
            tie_breaks: stats.tie_breaks,
        }
    }
}

/// One shard's main: a full virtual-time executor owning this shard's
/// jobs. Each job is spawned at `t0`, sleeps to its arrival offset, and
/// then runs the exact serial job body (driver chain, fingerprint) under
/// the shard's own clock; cross-shard ordering is the coordinator's
/// problem, not this function's.
fn shard_main(
    base: SimConfig,
    platform: Arc<SharedPlatform>,
    owned: Vec<(usize, Duration, JobRequest)>,
    sampling: bool,
) -> Vec<JobOutcome> {
    crate::rt::run_virtual(async move {
        let t0 = clock::now();
        let count = owned.len();
        let (tx, mut rx) = mpsc::unbounded::<JobOutcome>();
        for (idx, submitted, req) in owned {
            let job = JobId(idx as u64 + 1);
            let mut job_cfg = base.clone();
            job_cfg.seed = req.seed;
            let platform = Arc::clone(&platform);
            let tx = tx.clone();
            crate::rt::spawn(async move {
                crate::rt::sleep_until(t0 + submitted).await;
                let started = clock::now() - t0;
                let mut driver = EngineDriver::with_policy(job_cfg, req.policy)
                    .on_platform(platform)
                    .for_job(job)
                    .for_tenant(req.tenant);
                if sampling {
                    driver = driver.with_sampling();
                }
                let run = driver.run_forensic(&req.dag).await;
                let fingerprint = crate::sim::harness::fingerprint_outputs(&run.outputs);
                let _ = tx.send(JobOutcome {
                    job,
                    tenant: req.tenant,
                    name: req.name,
                    priority: req.priority,
                    cost_usd: 0.0, // filled by the post-hoc fold
                    submitted,
                    started,
                    finished: clock::now() - t0,
                    report: run.report,
                    fingerprint,
                    metrics: run.metrics,
                    kv: run.kv,
                    // kv_byte_budget == u64::MAX is validated at entry:
                    // the live arena is never reclaimed, so — exactly
                    // like the serial path — no snapshot is taken.
                    forensics: None,
                });
            });
        }
        drop(tx);
        let mut outs = Vec::with_capacity(count);
        while let Some(o) = rx.recv().await {
            outs.push(o);
        }
        outs
    })
}

/// Runs a whole service scenario to completion in deterministic virtual
/// time — the synchronous entry point (CLI `service` mode, tests,
/// benches). Dispatches on [`ServiceConfig::sim_shards`]: `1` runs the
/// serial loop on one fresh executor, `> 1` runs the conservative-PDES
/// sharded fleet ([`JobService::run_sharded`]); both render the same
/// canonical trace for the same configuration.
pub fn run_service(cfg: ServiceConfig, jobs: Vec<JobRequest>) -> ServiceReport {
    let service = JobService::new(cfg);
    if service.cfg.sim_shards > 1 {
        return service.run_sharded(jobs);
    }
    crate::rt::run_virtual(async move { service.run(jobs).await })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compute::Payload;
    use crate::dag::DagBuilder;
    use crate::engine::policies::{PubSubPolicy, WukongPolicy};

    fn chain_job(name: &str, tenant: u32, seed: u64, len: usize) -> JobRequest {
        let mut b = DagBuilder::new();
        let mut prev = b.add_task("t0", Payload::Sleep { ms: 5.0 }, 8, &[]);
        for i in 1..len {
            prev = b.add_task(format!("t{i}"), Payload::Sleep { ms: 5.0 }, 8, &[prev]);
        }
        JobRequest {
            name: name.to_string(),
            tenant,
            priority: 0,
            seed,
            dag: b.build().unwrap(),
            policy: Arc::new(WukongPolicy),
        }
    }

    #[test]
    fn arrival_profiles_are_deterministic_and_monotone() {
        for profile in [
            ArrivalProfile::Uniform { gap_ms: 10.0 },
            ArrivalProfile::Poisson { mean_gap_ms: 10.0 },
            ArrivalProfile::Bursts {
                burst: 4,
                intra_ms: 1.0,
                idle_ms: 100.0,
            },
        ] {
            let a = profile.arrival_offsets(16, 7);
            let b = profile.arrival_offsets(16, 7);
            assert_eq!(a, b, "{profile:?} must replay from its seed");
            assert_eq!(a[0], Duration::ZERO);
            assert!(a.windows(2).all(|w| w[0] <= w[1]), "{profile:?} monotone");
        }
        // Bursts: job 4 starts a new burst 100ms after job 3's burst slot.
        let bursts = ArrivalProfile::Bursts {
            burst: 4,
            intra_ms: 1.0,
            idle_ms: 100.0,
        }
        .arrival_offsets(8, 0);
        assert_eq!(bursts[3], Duration::from_millis(3));
        assert_eq!(bursts[4], Duration::from_millis(103));
    }

    #[test]
    fn service_completes_concurrent_jobs_over_one_platform() {
        let jobs: Vec<JobRequest> = (0..6)
            .map(|i| chain_job(&format!("chain{i}"), i % 2, 100 + i as u64, 4))
            .collect();
        let cfg = ServiceConfig::new(SimConfig::test(), 1)
            .with_profile(ArrivalProfile::Bursts {
                burst: 6,
                intra_ms: 0.0,
                idle_ms: 0.0,
            })
            .with_concurrency(6, 16);
        let report = run_service(cfg, jobs);
        assert_eq!(report.completed(), 6);
        assert!(report.all_ok(), "{}", report.fleet_row());
        assert!(report.rejected.is_empty());
        // Job ids are arrival order, 1-based.
        let ids: Vec<u64> = report.outcomes.iter().map(|o| o.job.0).collect();
        assert_eq!(ids, vec![1, 2, 3, 4, 5, 6]);
        for o in &report.outcomes {
            assert_eq!(o.report.job, o.job, "report carries the job id");
            assert_eq!(o.report.tasks_executed, 4, "{}", o.row());
            assert!(o.kv.is_some());
        }
        assert!(report.total_lambdas() >= 6);
    }

    #[test]
    fn admission_gate_limits_concurrent_jobs_and_queues_the_rest() {
        // 4 jobs, 1 slot: jobs must serialize — each waits for the
        // previous one, so queue delay grows monotonically.
        let jobs: Vec<JobRequest> = (0..4)
            .map(|i| chain_job(&format!("q{i}"), 0, i as u64, 3))
            .collect();
        let cfg = ServiceConfig::new(SimConfig::test(), 2)
            .with_profile(ArrivalProfile::Bursts {
                burst: 4,
                intra_ms: 0.0,
                idle_ms: 0.0,
            })
            .with_concurrency(1, 16);
        let report = run_service(cfg, jobs);
        assert_eq!(report.completed(), 4);
        assert!(report.all_ok());
        let delays: Vec<Duration> = report.outcomes.iter().map(|o| o.queue_delay()).collect();
        assert!(
            delays.windows(2).all(|w| w[0] <= w[1]),
            "serialized jobs queue in order: {delays:?}"
        );
        assert!(delays[3] > Duration::ZERO, "last job must have waited");
    }

    #[test]
    fn queue_cap_sheds_load() {
        // 5 jobs arrive at once; 1 runs, queue cap 2 => 2 shed.
        let jobs: Vec<JobRequest> = (0..5)
            .map(|i| chain_job(&format!("s{i}"), 0, i as u64, 3))
            .collect();
        let cfg = ServiceConfig::new(SimConfig::test(), 3)
            .with_profile(ArrivalProfile::Bursts {
                burst: 5,
                intra_ms: 0.0,
                idle_ms: 0.0,
            })
            .with_concurrency(1, 2);
        let report = run_service(cfg, jobs);
        assert_eq!(report.completed() + report.rejected.len(), 5);
        assert_eq!(report.rejected.len(), 2, "{}", report.fleet_row());
        assert!(report.all_ok());
    }

    #[test]
    fn queue_cap_zero_admits_into_free_slots_and_sheds_the_rest() {
        // 3 jobs at once, 2 slots, queue cap 0: two start immediately
        // (a free slot means no waiting, so cap 0 must not shed them);
        // the third would have to wait and is shed.
        let jobs: Vec<JobRequest> = (0..3)
            .map(|i| chain_job(&format!("z{i}"), 0, i as u64, 3))
            .collect();
        let cfg = ServiceConfig::new(SimConfig::test(), 6)
            .with_profile(ArrivalProfile::Bursts {
                burst: 3,
                intra_ms: 0.0,
                idle_ms: 0.0,
            })
            .with_concurrency(2, 0);
        let report = run_service(cfg, jobs);
        assert_eq!(report.completed(), 2, "{}", report.fleet_row());
        assert_eq!(report.rejected.len(), 1);
        assert!(report.all_ok());
        assert!(
            report.outcomes.iter().all(|o| o.queue_delay().is_zero()),
            "cap 0 means nothing ever waits"
        );
    }

    #[test]
    fn fair_admission_interleaves_tenants() {
        // Tenant 0 floods 3 jobs, tenant 1 submits 1, all at t=0, one
        // slot. FIFO admits 0,0,0,1; Fair must admit a tenant-1 job
        // second.
        let mk = |admission| {
            let mut jobs: Vec<JobRequest> = (0..3)
                .map(|i| chain_job(&format!("flood{i}"), 0, i as u64, 3))
                .collect();
            jobs.push(chain_job("minnow", 1, 9, 3));
            let cfg = ServiceConfig::new(SimConfig::test(), 4)
                .with_profile(ArrivalProfile::Bursts {
                    burst: 4,
                    intra_ms: 0.0,
                    idle_ms: 0.0,
                })
                .with_admission(admission)
                .with_concurrency(1, 16);
            run_service(cfg, jobs)
        };
        let fifo = mk(Admission::Fifo);
        let fair = mk(Admission::Fair);
        let start_of = |r: &ServiceReport, name: &str| {
            r.outcomes
                .iter()
                .find(|o| o.name == name)
                .expect("job completed")
                .started
        };
        assert!(
            start_of(&fair, "minnow") < start_of(&fifo, "minnow"),
            "fair admission must start the minority tenant earlier"
        );
        // Under fair, only the first flood job may start before the
        // minnow (it arrived first into an empty queue).
        let fair_minnow = start_of(&fair, "minnow");
        let floods_before = fair
            .outcomes
            .iter()
            .filter(|o| o.tenant == 0 && o.started < fair_minnow)
            .count();
        assert!(floods_before <= 1, "got {floods_before} flood jobs first");
    }

    #[test]
    fn mixed_policies_share_the_platform() {
        // A decentralized and a centralized job concurrently over one
        // shared platform + KV cluster: both complete, channels and
        // arenas stay isolated.
        let mut jobs = vec![chain_job("wukong-job", 0, 1, 4)];
        let mut pubsub_job = chain_job("pubsub-job", 1, 2, 4);
        pubsub_job.policy = Arc::new(PubSubPolicy);
        jobs.push(pubsub_job);
        let cfg = ServiceConfig::new(SimConfig::test(), 5)
            .with_profile(ArrivalProfile::Bursts {
                burst: 2,
                intra_ms: 0.0,
                idle_ms: 0.0,
            })
            .with_concurrency(2, 8);
        let report = run_service(cfg, jobs);
        assert_eq!(report.completed(), 2);
        assert!(report.all_ok(), "{}", report.fleet_row());
        let trace = report.render_trace();
        assert!(trace.starts_with("service completed=2 rejected=0 "));
        assert!(trace.contains("outcome job1 "));
        assert!(trace.contains("outcome job2 "));
    }

    #[test]
    fn priority_admission_preempts_queued_lowest_first() {
        // Six jobs, priorities 0..5, all at t=0, ONE slot, queue cap 2.
        // Arrival walkthrough: job0 admits into the free slot; jobs 1, 2
        // queue; each later (higher-priority) arrival preempts the
        // lowest-priority queued job. Completions then drain the queue
        // highest-priority-first: 0 (running), then 5, then 4.
        let jobs: Vec<JobRequest> = (0..6)
            .map(|i| {
                let mut j = chain_job(&format!("p{i}"), 0, i as u64, 3);
                j.priority = i as u8;
                j
            })
            .collect();
        let cfg = ServiceConfig::new(SimConfig::test(), 7)
            .with_profile(ArrivalProfile::Bursts {
                burst: 6,
                intra_ms: 0.0,
                idle_ms: 0.0,
            })
            .with_admission(Admission::Priority)
            .with_concurrency(1, 2);
        let report = run_service(cfg, jobs);
        assert!(report.all_ok());
        let completed: Vec<String> = report.outcomes.iter().map(|o| o.name.clone()).collect();
        assert_eq!(completed, vec!["p0", "p4", "p5"], "{}", report.fleet_row());
        let shed: Vec<(String, ShedReason)> = report
            .rejected
            .iter()
            .map(|s| (s.name.clone(), s.reason))
            .collect();
        assert_eq!(
            shed,
            vec![
                ("p1".to_string(), ShedReason::Preempted),
                ("p2".to_string(), ShedReason::Preempted),
                ("p3".to_string(), ShedReason::Preempted),
            ]
        );
        // Queued-preemption only: every started job ran to completion.
        let start_order: Vec<&str> = {
            let mut by_start: Vec<&JobOutcome> = report.outcomes.iter().collect();
            by_start.sort_by_key(|o| o.started);
            by_start.iter().map(|o| o.name.as_str()).collect()
        };
        assert_eq!(start_order, vec!["p0", "p5", "p4"]);
    }

    #[test]
    fn tenant_budget_sheds_over_budget_tenant_only() {
        // Tenant 0 submits three jobs spaced far apart, tenant 1 one job.
        // The budget covers roughly one job's cost, so tenant 0's later
        // arrivals are shed with the budget reason while tenant 1 runs.
        let jobs = vec![
            chain_job("t0-a", 0, 1, 3),
            chain_job("t0-b", 0, 2, 3),
            chain_job("t1-a", 1, 3, 3),
            chain_job("t0-c", 0, 4, 3),
        ];
        let cfg = ServiceConfig::new(SimConfig::test(), 8)
            .with_profile(ArrivalProfile::Uniform { gap_ms: 5000.0 })
            .with_concurrency(4, 16)
            // Below one chain job's cost (>= one 100 ms billing unit at
            // 3 GB ≈ 5e-6 USD), so the first completion trips the budget.
            .with_tenant_budget(1e-6);
        let report = run_service(cfg, jobs);
        let completed: Vec<&str> = report.outcomes.iter().map(|o| o.name.as_str()).collect();
        assert!(completed.contains(&"t0-a"), "{completed:?}");
        assert!(completed.contains(&"t1-a"), "tenant 1 is unaffected");
        let budget_shed: Vec<&str> = report
            .rejected
            .iter()
            .filter(|s| s.reason == ShedReason::Budget)
            .map(|s| s.name.as_str())
            .collect();
        assert_eq!(budget_shed, vec!["t0-b", "t0-c"], "{}", report.fleet_row());
        // The ledger records the spend that tripped the budget.
        let spent0 = report
            .tenant_spend
            .iter()
            .find(|&&(t, _)| t == 0)
            .map(|&(_, s)| s)
            .unwrap();
        assert!(spent0 >= 1e-6, "tenant 0 spent {spent0}");
        assert!(report.outcomes.iter().all(|o| o.cost_usd > 0.0));
    }

    #[test]
    fn budget_refill_pauses_over_budget_jobs_until_the_next_window() {
        // Same regime as the shed test — the budget covers less than one
        // job, and the second arrival lands after the first completion
        // tripped it — but with the refill armed the job *parks* in the
        // queue and runs once the window boundary raises the effective
        // budget, instead of being shed.
        let jobs = vec![chain_job("t0-a", 0, 1, 3), chain_job("t0-b", 0, 2, 3)];
        let cfg = ServiceConfig::new(SimConfig::test(), 8)
            .with_profile(ArrivalProfile::Uniform { gap_ms: 5000.0 })
            .with_concurrency(4, 16)
            .with_tenant_budget(1e-6)
            // One dollar per 10 s window: at t0-b's 5 s arrival no window
            // has elapsed (still over budget -> parked), at 10 s the
            // first refill clears it.
            .with_budget_refill(1.0, Duration::from_secs(10));
        assert!(cfg.refill_active());
        let report = run_service(cfg, jobs);
        assert!(
            report.rejected.is_empty(),
            "refill pauses instead of shedding: {:?}",
            report
                .rejected
                .iter()
                .map(|s| (s.name.clone(), s.reason))
                .collect::<Vec<_>>()
        );
        assert_eq!(report.completed(), 2);
        assert!(report.all_ok());
        let b = report.outcomes.iter().find(|o| o.name == "t0-b").unwrap();
        assert_eq!(b.submitted, Duration::from_secs(5));
        assert!(
            b.started >= Duration::from_secs(10),
            "parked until the first refill boundary, started at {:?}",
            b.started
        );
    }

    #[test]
    fn recorded_profile_replays_offsets_verbatim() {
        let profile = ArrivalProfile::Recorded {
            offsets_ns: vec![0, 5_000_000, 7_000_000],
        };
        // The arrival seed is ignored: any seed replays the same offsets.
        let a = profile.arrival_offsets(3, 1);
        let b = profile.arrival_offsets(3, 999);
        assert_eq!(a, b);
        assert_eq!(
            a,
            vec![
                Duration::ZERO,
                Duration::from_millis(5),
                Duration::from_nanos(7_000_000)
            ]
        );
        // Beyond the recorded length the last offset repeats; a shorter
        // request truncates.
        assert_eq!(profile.arrival_offsets(5, 1)[4], Duration::from_nanos(7_000_000));
        assert_eq!(profile.arrival_offsets(2, 1).len(), 2);
    }

    #[test]
    fn live_session_in_virtual_time_records_and_replays_fingerprints() {
        // Submissions queued before the executor starts all land at the
        // same virtual instant, so the three jobs run concurrently and
        // complete out of arrival order (short before long). The
        // recording fed back through the classic service must reproduce
        // every job's sink fingerprint.
        let lens: &[(&str, u32, u64, usize)] =
            &[("long", 0, 11, 8), ("short", 1, 12, 2), ("tail", 0, 13, 3)];
        let cfg = ServiceConfig::new(SimConfig::test(), 3).with_concurrency(4, 16);
        let service = JobService::new(cfg.clone());
        let (tx, rx) = mpsc::unbounded::<LiveSubmission>();
        for &(name, tenant, seed, len) in lens {
            let _ = tx.send(LiveSubmission {
                req: chain_job(name, tenant, seed, len),
                spec: format!("chain:{len} name={name} tenant={tenant} seed={seed}"),
            });
        }
        drop(tx);
        let (live, recording) =
            crate::rt::run_virtual(async move { service.run_live(rx, Arc::new(())).await });
        assert_eq!(live.completed(), 3);
        assert!(live.all_ok());
        assert!(live.rejected.is_empty());
        assert_eq!(recording.jobs.len(), 3);
        assert_eq!(recording.jobs[0].name, "long");
        assert!(recording.render().contains("arrival 2 offset_ns="));
        // Out-of-order completion: the later-arriving short chain ends
        // before the first-arriving long one.
        let finished = |n: &str| live.outcomes.iter().find(|o| o.name == n).unwrap().finished;
        assert!(finished("long") > finished("short"));

        let replay_jobs: Vec<JobRequest> = recording
            .jobs
            .iter()
            .map(|r| {
                let len = lens.iter().find(|l| l.0 == r.name).unwrap().3;
                chain_job(&r.name, r.tenant, r.seed, len)
            })
            .collect();
        let replay = run_service(cfg.with_profile(recording.replay_profile()), replay_jobs);
        assert_eq!(replay.completed(), 3);
        assert!(replay.rejected.is_empty());
        for (a, b) in live.outcomes.iter().zip(&replay.outcomes) {
            assert_eq!(a.job, b.job);
            assert_eq!(a.name, b.name);
            assert_eq!(a.fingerprint, b.fingerprint, "{} fingerprint", a.name);
        }
    }

    #[test]
    fn recorded_wall_session_with_out_of_order_completion_replays_identically() {
        // The satellite scenario: a *wall-clock* session (Mode::Real —
        // modeled sleeps really sleep) where a short job submitted after
        // a long one finishes first. The recorded trace replayed through
        // the virtual-time service must reproduce the fingerprints and
        // the (empty) shed set.
        let cfg = ServiceConfig::new(SimConfig::test(), 3).with_concurrency(4, 16);
        let service = JobService::new(cfg.clone());
        let (tx, rx) = mpsc::unbounded::<LiveSubmission>();
        let submitter = std::thread::spawn(move || {
            let _ = tx.send(LiveSubmission {
                req: chain_job("long", 0, 21, 10),
                spec: "chain:10 name=long".to_string(),
            });
            std::thread::sleep(std::time::Duration::from_millis(10));
            let _ = tx.send(LiveSubmission {
                req: chain_job("short", 1, 22, 2),
                spec: "chain:2 name=short".to_string(),
            });
        });
        let (live, recording) = crate::rt::block_on(
            async move { service.run_live(rx, Arc::new(())).await },
            crate::rt::Mode::Real,
        );
        submitter.join().unwrap();
        assert_eq!(live.completed(), 2);
        assert!(live.all_ok());
        let finished = |n: &str| live.outcomes.iter().find(|o| o.name == n).unwrap().finished;
        assert!(
            finished("long") > finished("short"),
            "10x5ms chain outlives a 2x5ms chain submitted 10ms later"
        );
        assert!(recording.jobs[0].offset_ns <= recording.jobs[1].offset_ns);

        let replay_jobs: Vec<JobRequest> = recording
            .jobs
            .iter()
            .map(|r| {
                let len = if r.name == "long" { 10 } else { 2 };
                chain_job(&r.name, r.tenant, r.seed, len)
            })
            .collect();
        let replay = run_service(cfg.with_profile(recording.replay_profile()), replay_jobs);
        assert_eq!(replay.completed(), 2);
        assert!(replay.rejected.is_empty(), "shed decisions match the live run");
        for (a, b) in live.outcomes.iter().zip(&replay.outcomes) {
            assert_eq!(a.job, b.job);
            assert_eq!(a.name, b.name);
            assert_eq!(a.fingerprint, b.fingerprint, "{} fingerprint", a.name);
        }
    }

    #[test]
    fn shed_jobs_leave_no_substrate_and_budget_zero_reclaims_all() {
        // The shed-path leak regression: more arrivals than queue_cap
        // admits, under a zero KV byte budget. After the run the shared
        // substrate must be completely empty — no arena registry entries,
        // no resident bytes, no broker namespaces — because shed jobs
        // never touch the substrate and completed jobs are retired and
        // evicted.
        let jobs: Vec<JobRequest> = (0..6)
            .map(|i| chain_job(&format!("s{i}"), i % 2, i as u64, 3))
            .collect();
        let cfg = ServiceConfig::new(SimConfig::test(), 9)
            .with_profile(ArrivalProfile::Bursts {
                burst: 6,
                intra_ms: 0.0,
                idle_ms: 0.0,
            })
            .with_concurrency(1, 1)
            .with_kv_budget(0);
        let report = run_service(cfg, jobs);
        assert!(!report.rejected.is_empty(), "cap 1 must shed some of 6");
        assert_eq!(report.completed() + report.rejected.len(), 6);
        assert_eq!(report.resident_kv_bytes, 0, "no resident bytes survive");
        assert_eq!(report.pubsub_namespaces, 0, "no broker namespaces survive");
        assert_eq!(report.registered_arenas, 0, "no arenas stay registered");
        // Every completed job was evicted, oldest-finished-first.
        assert_eq!(report.evicted.len(), report.completed());
        let finished_of = |job: &JobId| {
            report
                .outcomes
                .iter()
                .find(|o| o.job == *job)
                .unwrap()
                .finished
        };
        assert!(
            report.evicted.windows(2).all(|w| finished_of(&w[0]) <= finished_of(&w[1])),
            "eviction follows completion order: {:?}",
            report.evicted
        );
        // The pre-retirement snapshots survive for forensics.
        for o in &report.outcomes {
            let f = o.forensics.as_ref().expect("wukong jobs have arenas");
            assert!(!f.object_keys.is_empty(), "{}: snapshot kept", o.name);
            let kv = o.kv.as_ref().unwrap();
            assert_eq!(kv.resident_bytes(), 0, "{}: live arena evicted", o.name);
        }
    }

    #[test]
    fn finite_kv_budget_retains_newest_finished_jobs() {
        // A budget big enough for roughly one job's intermediates:
        // eviction must free the oldest finished jobs and retain the
        // rest, and the end state must replay deterministically.
        let run = || {
            let jobs: Vec<JobRequest> = (0..4)
                .map(|i| chain_job(&format!("b{i}"), 0, i as u64, 4))
                .collect();
            let cfg = ServiceConfig::new(SimConfig::test(), 11)
                .with_profile(ArrivalProfile::Bursts {
                    burst: 4,
                    intra_ms: 0.0,
                    idle_ms: 0.0,
                })
                .with_concurrency(1, 16)
                .with_kv_budget(10); // each chain sink is 8 bytes resident
            run_service(cfg, jobs)
        };
        let report = run();
        assert_eq!(report.completed(), 4);
        // 4 jobs x 8 resident bytes, budget 10: three oldest evicted.
        assert_eq!(report.evicted.len(), 3, "{:?}", report.evicted);
        assert_eq!(report.resident_kv_bytes, 8);
        assert_eq!(report.registered_arenas, 1);
        let replay = run();
        assert_eq!(replay.evicted, report.evicted, "eviction is deterministic");
        assert_eq!(replay.render_trace(), report.render_trace());
    }

    #[test]
    fn spill_service_bills_storage_seconds_into_the_tenant_ledger() {
        // Budget 0 + spill on: every completed job's intermediates
        // demote to the cold tier instead of dying, and the end-of-run
        // settlement bills each tenant the storage-seconds on top of
        // its job costs. Storage priced at $1/GB-s so the (tiny) bill
        // is unmistakably visible in the ledger.
        let run = || {
            let jobs: Vec<JobRequest> = (0..4)
                .map(|i| chain_job(&format!("sp{i}"), i % 2, i as u64, 4))
                .collect();
            let mut cfg = ServiceConfig::new(SimConfig::test(), 11)
                .with_profile(ArrivalProfile::Bursts {
                    burst: 4,
                    intra_ms: 0.0,
                    idle_ms: 0.0,
                })
                .with_concurrency(1, 16)
                .with_kv_budget(0)
                .with_spill(true);
            cfg.spill_cost_gb_s = 1.0;
            run_service(cfg, jobs)
        };
        let report = run();
        assert_eq!(report.completed(), 4);
        assert_eq!(report.evicted.len(), 4, "budget 0 evicts every job");
        // Each chain job retains its 8-byte sink: demoted, not destroyed.
        assert_eq!(report.spill_demoted_bytes, 32);
        assert_eq!(report.spill_reads, 0, "nobody fetched late");
        assert!(
            report.spill_gb_seconds > 0.0,
            "sets accrued storage-seconds until end-of-run settlement"
        );
        assert!(report.spill_cost_usd > 0.0);
        // The tenant ledger carries job costs PLUS the storage bill.
        let job_costs: f64 = report.outcomes.iter().map(|o| o.cost_usd).sum();
        let ledger: f64 = report.tenant_spend.iter().map(|&(_, s)| s).sum();
        assert!(ledger > job_costs, "storage bill lands in the ledger");
        assert!(
            (ledger - job_costs - report.spill_cost_usd).abs() < 1e-12,
            "ledger = job costs + spill settlement"
        );
        // The cluster itself is empty (demotion zeroes the KV ledger);
        // the trace gains a spill line and still replays byte-identically.
        assert_eq!(report.resident_kv_bytes, 0);
        assert_eq!(report.registered_arenas, 0);
        let trace = report.render_trace();
        assert!(trace.contains("\nspill demoted_bytes=32 reads=0 "), "{trace}");
        assert_eq!(run().render_trace(), trace, "spill runs replay exactly");
    }

    #[test]
    fn spill_armed_but_unbudgeted_is_bit_identical_to_spill_off() {
        // With an unlimited byte budget nothing is ever evicted, so an
        // armed spill tier must change NOTHING: the canonical trace is
        // byte-identical to the spill-off run (which is itself the
        // pre-spill engine — eviction-as-destruction semantics and all).
        let run = |spill: bool| {
            let jobs: Vec<JobRequest> = (0..4)
                .map(|i| chain_job(&format!("in{i}"), i % 2, i as u64, 4))
                .collect();
            let cfg = ServiceConfig::new(SimConfig::test(), 12)
                .with_profile(ArrivalProfile::Bursts {
                    burst: 4,
                    intra_ms: 0.0,
                    idle_ms: 0.0,
                })
                .with_concurrency(2, 16)
                .with_spill(spill);
            run_service(cfg, jobs)
        };
        let off = run(false);
        let armed = run(true);
        assert_eq!(armed.spill_demoted_bytes, 0);
        assert_eq!(armed.spill_gb_seconds, 0.0);
        assert_eq!(off.render_trace(), armed.render_trace());
    }

    fn fan_job(name: &str, tenant: u32, seed: u64) -> JobRequest {
        let mut b = DagBuilder::new();
        let src = b.add_task("src", Payload::Sleep { ms: 3.0 }, 64, &[]);
        let kids: Vec<_> = (0..4)
            .map(|i| b.add_task(format!("c{i}"), Payload::Sleep { ms: 2.0 }, 32, &[src]))
            .collect();
        b.add_task("sink", Payload::Sleep { ms: 1.0 }, 8, &kids);
        JobRequest {
            name: name.to_string(),
            tenant,
            priority: 0,
            seed,
            dag: b.build().unwrap(),
            policy: Arc::new(WukongPolicy),
        }
    }

    /// A mixed contention-free fleet for the sharded-equivalence tests:
    /// chains, fan-outs, and one centralized job, two tenants, Poisson
    /// arrivals (distinct fractional-nanosecond offsets keep cross-job
    /// events off a shared time lattice).
    fn sharded_fleet() -> Vec<JobRequest> {
        let mut jobs: Vec<JobRequest> = (0..6)
            .map(|i| {
                if i % 2 == 0 {
                    chain_job(&format!("chain{i}"), i % 2, 200 + i as u64, 4)
                } else {
                    fan_job(&format!("fan{i}"), i % 2, 300 + i as u64)
                }
            })
            .collect();
        let mut central = chain_job("central", 1, 7, 3);
        central.policy = Arc::new(PubSubPolicy);
        jobs.push(central);
        jobs
    }

    fn sharded_cfg() -> ServiceConfig {
        ServiceConfig::new(SimConfig::test(), 13)
            .with_profile(ArrivalProfile::Poisson { mean_gap_ms: 20.0 })
            .with_concurrency(16, 16)
    }

    #[test]
    fn sharded_clocks_replay_the_serial_service_byte_for_byte() {
        // THE tentpole invariant: for every shard count the canonical
        // trace — completions, virtual timestamps, ledgers, substrate
        // state — is byte-identical to the serial single-executor run.
        let serial = run_service(sharded_cfg(), sharded_fleet());
        assert_eq!(serial.completed(), 7);
        assert!(serial.all_ok(), "{}", serial.fleet_row());
        let serial_trace = serial.render_trace();
        for shards in [2usize, 3, 8] {
            let report = run_service(sharded_cfg().with_shards(shards), sharded_fleet());
            assert_eq!(
                report.render_trace(),
                serial_trace,
                "{shards} shards diverged from the serial trace"
            );
            assert_eq!(
                report.tie_breaks, 0,
                "{shards} shards: distinct Poisson arrivals must keep cross-shard \
                 events off a shared instant"
            );
            // Fingerprints are covered by the trace only indirectly;
            // pin the sink digests themselves too.
            for (a, b) in report.outcomes.iter().zip(serial.outcomes.iter()) {
                assert_eq!(a.fingerprint, b.fingerprint, "job {} ({shards} shards)", a.job);
            }
        }
    }

    #[test]
    fn one_shard_config_is_the_serial_path_bit_for_bit() {
        // sim_shards = 1 must not merely be equivalent — it IS the serial
        // code path (run_service dispatches to the sharded fleet only
        // above 1), pinned here against the default config.
        let default_run = run_service(sharded_cfg(), sharded_fleet());
        let one_shard = run_service(sharded_cfg().with_shards(1), sharded_fleet());
        assert_eq!(one_shard.render_trace(), default_run.render_trace());
    }

    #[test]
    #[should_panic(expected = "contention-free admission")]
    fn sharded_service_rejects_admission_contention() {
        let jobs: Vec<JobRequest> = (0..4)
            .map(|i| chain_job(&format!("c{i}"), 0, i as u64, 3))
            .collect();
        let cfg = ServiceConfig::new(SimConfig::test(), 1)
            .with_concurrency(1, 16)
            .with_shards(2);
        run_service(cfg, jobs);
    }

    #[test]
    #[should_panic(expected = "benign shared fault streams")]
    fn sharded_service_rejects_shared_fault_streams() {
        let mut base = SimConfig::test();
        base.faults.crash_prob = 0.1;
        let cfg = ServiceConfig::new(base, 1).with_concurrency(16, 16).with_shards(2);
        run_service(cfg, vec![chain_job("c", 0, 1, 3)]);
    }

    #[test]
    fn unit_nic_class_weights_are_bit_identical_to_no_weights() {
        // Weight 1 is the implicit default: registering it explicitly
        // for every tenant must leave the DRR — and the whole trace —
        // untouched (the satellite's single-class inertness pin, at
        // service level where the tenant -> weight resolution lives).
        let run = |weights: Vec<(u32, u64)>| {
            let mut base = SimConfig::test();
            base.net.nic_drr_class_weights = weights;
            let jobs: Vec<JobRequest> = (0..4)
                .map(|i| chain_job(&format!("w{i}"), i % 2, 400 + i as u64, 4))
                .collect();
            let cfg = ServiceConfig::new(base, 14)
                .with_profile(ArrivalProfile::Poisson { mean_gap_ms: 10.0 })
                .with_concurrency(4, 8);
            run_service(cfg, jobs)
        };
        let plain = run(Vec::new());
        let unit = run(vec![(0, 1), (1, 1)]);
        assert_eq!(unit.render_trace(), plain.render_trace());
    }

    #[test]
    fn class_weights_plumb_through_the_sharded_path() {
        // A weighted tenant class must produce the same (weighted) trace
        // under sharding as under the serial loop — weights and shards
        // compose.
        let run = |shards: usize| {
            let mut base = SimConfig::test();
            base.net.nic_drr_class_weights = vec![(1, 4)];
            let jobs: Vec<JobRequest> = (0..4)
                .map(|i| fan_job(&format!("wf{i}"), i % 2, 500 + i as u64))
                .collect();
            let cfg = ServiceConfig::new(base, 15)
                .with_profile(ArrivalProfile::Poisson { mean_gap_ms: 15.0 })
                .with_concurrency(8, 8)
                .with_shards(shards);
            run_service(cfg, jobs)
        };
        assert_eq!(run(2).render_trace(), run(1).render_trace());
    }
}
