//! The PJRT actor: one OS thread owning a `PjRtClient` and the compiled
//! executable cache, serving execute requests over a channel.

use crate::compute::Tensor;
use crate::core::{EngineError, EngineResult};
use crate::rt::sync::{mpsc, oneshot};
#[cfg(feature = "xla")]
use std::collections::HashMap;
#[cfg(feature = "xla")]
use std::path::Path;
use std::path::PathBuf;
use std::sync::Arc;

enum Request {
    Execute {
        artifact: String,
        inputs: Vec<Arc<Tensor>>,
        reply: oneshot::Sender<EngineResult<Tensor>>,
    },
    /// Preload (compile) an artifact without executing it.
    Warm {
        artifact: String,
        reply: oneshot::Sender<EngineResult<()>>,
    },
}

/// Send + Sync handle to the PJRT actor thread.
#[derive(Clone)]
pub struct PjrtRuntime {
    tx: mpsc::Sender<Request>,
}

impl std::fmt::Debug for PjrtRuntime {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "PjrtRuntime")
    }
}

impl PjrtRuntime {
    /// Starts the actor thread with artifacts from `dir`
    /// (`<dir>/<name>.hlo.txt`).
    #[cfg(feature = "xla")]
    pub fn new(dir: impl Into<PathBuf>) -> EngineResult<Self> {
        let dir = dir.into();
        let (tx, rx) = mpsc::unbounded();
        let (ready_tx, ready_rx) = std::sync::mpsc::channel();
        std::thread::Builder::new()
            .name("pjrt-actor".into())
            .spawn(move || actor_main(dir, rx, ready_tx))
            .map_err(|e| EngineError::Runtime(format!("spawn pjrt thread: {e}")))?;
        // Propagate client-construction errors synchronously.
        match ready_rx.recv() {
            Ok(Ok(())) => Ok(PjrtRuntime { tx }),
            Ok(Err(e)) => Err(e),
            Err(_) => Err(EngineError::Runtime("pjrt actor died at startup".into())),
        }
    }

    /// Stub for builds without the `xla` feature (the offline build image
    /// does not vendor the `xla` crate): constructing the runtime reports
    /// a clear error, and every simulation-mode payload keeps working.
    #[cfg(not(feature = "xla"))]
    pub fn new(dir: impl Into<PathBuf>) -> EngineResult<Self> {
        let _ = dir.into();
        Err(EngineError::Runtime(
            "wukong was built without the `xla` feature: the PJRT real-compute \
             backend is unavailable (simulation-mode payloads run everywhere)"
                .into(),
        ))
    }

    /// Default artifacts directory: `$WUKONG_ARTIFACTS` or `./artifacts`.
    pub fn artifacts_dir() -> PathBuf {
        std::env::var("WUKONG_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|_| PathBuf::from("artifacts"))
    }

    /// Executes `artifact` over `inputs`, returning the output tensor.
    /// Must be awaited inside an rt executor; the completion arrives from
    /// the actor thread (registered as an external operation so an idle
    /// virtual-time executor waits instead of declaring deadlock).
    pub async fn execute(
        &self,
        artifact: &str,
        inputs: Vec<Arc<Tensor>>,
    ) -> EngineResult<Tensor> {
        let (reply, rx) = oneshot::channel();
        let _guard = crate::rt::ExternalGuard::register();
        self.tx
            .send(Request::Execute {
                artifact: artifact.to_string(),
                inputs,
                reply,
            })
            .map_err(|_| EngineError::Runtime("pjrt actor gone".into()))?;
        rx.await
            .map_err(|_| EngineError::Runtime("pjrt actor dropped reply".into()))?
    }

    /// Compiles `artifact` ahead of time (dedup'd by the cache).
    pub async fn warm(&self, artifact: &str) -> EngineResult<()> {
        let (reply, rx) = oneshot::channel();
        let _guard = crate::rt::ExternalGuard::register();
        self.tx
            .send(Request::Warm {
                artifact: artifact.to_string(),
                reply,
            })
            .map_err(|_| EngineError::Runtime("pjrt actor gone".into()))?;
        rx.await
            .map_err(|_| EngineError::Runtime("pjrt actor dropped reply".into()))?
    }

    /// Blocking variant for non-async contexts (examples, tests).
    pub fn execute_blocking(
        &self,
        artifact: &str,
        inputs: Vec<Arc<Tensor>>,
    ) -> EngineResult<Tensor> {
        let (reply, rx) = oneshot::channel();
        self.tx
            .send(Request::Execute {
                artifact: artifact.to_string(),
                inputs,
                reply,
            })
            .map_err(|_| EngineError::Runtime("pjrt actor gone".into()))?;
        crate::rt::block_on_simple(rx)
            .map_err(|_| EngineError::Runtime("pjrt actor dropped reply".into()))?
    }
}

#[cfg(feature = "xla")]
fn actor_main(
    dir: PathBuf,
    mut rx: mpsc::Receiver<Request>,
    ready: std::sync::mpsc::Sender<EngineResult<()>>,
) {
    let client = match xla::PjRtClient::cpu() {
        Ok(c) => {
            let _ = ready.send(Ok(()));
            c
        }
        Err(e) => {
            let _ = ready.send(Err(EngineError::Runtime(format!(
                "PjRtClient::cpu failed: {e}"
            ))));
            return;
        }
    };
    let mut cache: HashMap<String, xla::PjRtLoadedExecutable> = HashMap::new();

    while let Some(req) = rx.blocking_recv() {
        match req {
            Request::Execute {
                artifact,
                inputs,
                reply,
            } => {
                let r = get_exe(&client, &mut cache, &dir, &artifact)
                    .and_then(|exe| run(exe, &inputs));
                let _ = reply.send(r);
            }
            Request::Warm { artifact, reply } => {
                let r = get_exe(&client, &mut cache, &dir, &artifact).map(|_| ());
                let _ = reply.send(r);
            }
        }
    }
}

#[cfg(feature = "xla")]
fn get_exe<'a>(
    client: &xla::PjRtClient,
    cache: &'a mut HashMap<String, xla::PjRtLoadedExecutable>,
    dir: &Path,
    artifact: &str,
) -> EngineResult<&'a xla::PjRtLoadedExecutable> {
    if !cache.contains_key(artifact) {
        let path = dir.join(format!("{artifact}.hlo.txt"));
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str()
                .ok_or_else(|| EngineError::Runtime("non-utf8 artifact path".into()))?,
        )
        .map_err(|e| EngineError::Runtime(format!("load {path:?}: {e}")))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client
            .compile(&comp)
            .map_err(|e| EngineError::Runtime(format!("compile {artifact}: {e}")))?;
        cache.insert(artifact.to_string(), exe);
    }
    Ok(cache.get(artifact).unwrap())
}

#[cfg(feature = "xla")]
fn run(exe: &xla::PjRtLoadedExecutable, inputs: &[Arc<Tensor>]) -> EngineResult<Tensor> {
    let literals: Vec<xla::Literal> = inputs
        .iter()
        .map(|t| tensor_to_literal(t))
        .collect::<EngineResult<_>>()?;
    let result = exe
        .execute::<xla::Literal>(&literals)
        .map_err(|e| EngineError::Runtime(format!("execute: {e}")))?;
    let lit = result[0][0]
        .to_literal_sync()
        .map_err(|e| EngineError::Runtime(format!("to_literal: {e}")))?;
    // aot.py lowers with return_tuple=True: unwrap the 1-tuple.
    let out = lit
        .to_tuple1()
        .map_err(|e| EngineError::Runtime(format!("to_tuple1: {e}")))?;
    literal_to_tensor(&out)
}

#[cfg(feature = "xla")]
fn tensor_to_literal(t: &Tensor) -> EngineResult<xla::Literal> {
    let dims: Vec<i64> = t.shape.iter().map(|&d| d as i64).collect();
    xla::Literal::vec1(&t.data)
        .reshape(&dims)
        .map_err(|e| EngineError::Runtime(format!("reshape{:?}: {e}", t.shape)))
}

#[cfg(feature = "xla")]
fn literal_to_tensor(lit: &xla::Literal) -> EngineResult<Tensor> {
    let shape = lit
        .array_shape()
        .map_err(|e| EngineError::Runtime(format!("shape: {e}")))?;
    let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
    let data = lit
        .to_vec::<f32>()
        .map_err(|e| EngineError::Runtime(format!("to_vec: {e}")))?;
    Ok(Tensor::new(dims, data))
}
