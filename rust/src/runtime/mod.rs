//! PJRT runtime bridge (real-compute mode).
//!
//! Loads the AOT artifacts produced by `python/compile/aot.py`
//! (`artifacts/*.hlo.txt`, HLO **text** — see DESIGN.md and
//! /opt/xla-example/README.md for why text, not serialized protos), compiles
//! them once on a PJRT CPU client, and executes them from the engine's hot
//! path.
//!
//! The `xla` crate's client types are `Rc`-based (not `Send`), while the
//! engine spawns executors onto a tokio runtime. The runtime therefore runs
//! as an **actor on a dedicated OS thread** owning the client and the
//! compiled-executable cache; the [`PjrtRuntime`] handle is Send + Sync and
//! cheap to clone into every executor.

pub mod pjrt;

pub use pjrt::PjrtRuntime;
