//! The cold spill tier: S3-class object storage behind the KV cluster.
//!
//! The storage hierarchy is executor LocalCache → KV cluster → spill
//! tier. When [`crate::kvstore::KvStore::enforce_kv_budget`] evicts a
//! retired arena and spill is enabled, the arena's payload objects
//! demote here instead of being destroyed: a late `get` falls through
//! the (now empty) KV cluster, finds the object in the spill set, and
//! pays the cold tier's latency + streaming-bandwidth penalty — no more
//! `MissingObject` for result-fetch-after-completion. The tier also
//! runs a storage-seconds meter: every byte parked here accrues
//! GB-seconds from demotion until purge, and the job service settles
//! that accrual into the owning tenant's dollar ledger at end of run.
//!
//! ## Determinism
//!
//! The cold-read latency tail is a seeded [`TailLatency`] stream (its
//! own stream salt, so arming the tier never perturbs the KV cluster's
//! draws), and `purge_all` settles sets in registration-uid order, so
//! identical runs produce identical settlements and traces. The tier
//! never calls the virtual clock itself: every mutation takes the
//! caller's `now`, and a high-water mark of the latest observed instant
//! lets [`crate::kvstore::JobArena`]'s `Drop` — which may run *outside*
//! the virtual-time executor, where the clock would panic — settle its
//! spill set deterministically.
//!
//! ## Capacity cap
//!
//! `SpillConfig::max_spill_bytes` bounds the tier. A demotion that would
//! push the parked total past the cap **deletes** the oldest spill sets
//! (smallest registration uid — the deterministic demotion order) until
//! the total fits; a set too large to ever fit deletes itself. Deletion
//! is real: a late `get` of a deleted object is `MissingObject` again,
//! exactly as if the tier were disabled for that set. Victims settle
//! their storage-seconds at the deletion instant into a pending-bill
//! queue that [`SpillTier::purge_all`] drains ahead of the end-of-run
//! settlements, so the owning tenants still pay for the residency they
//! used. The `u64::MAX` default never deletes — bit-identical to the
//! uncapped tier.
//!
//! ## Promotion on repeated cold reads
//!
//! `SpillConfig::promote_after_reads = N` (0 = off, the default) turns
//! the Nth cold read of an object into a **promotion**: the object
//! leaves its spill set — the set's storage-seconds settle at the
//! promotion instant into the pending-bill queue (the cap-deletion
//! pattern, so the owning tenant still pays for the residency) and the
//! meter restarts at the reduced size — and the caller re-inserts the
//! bytes into its warm arena, so further reads skip the cold penalty.
//! With the knob at 0 [`SpillTier::read_promoting`] is byte-identical
//! to [`SpillTier::read`].
//!
//! With `SpillConfig::enabled = false` (the default) every method is a
//! no-op returning "absent", so eviction remains destruction and the
//! engine is bit-identical to the pre-spill behavior.

use crate::compute::DataObj;
use crate::core::{FaultConfig, SimInstant, SpillConfig};
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Duration;

/// Spill-tail stream salt ("spill" in ASCII-ish hex), distinct from the
/// arena tail salt so arming the tier never shifts KV latency draws.
const SPILL_SALT: u64 = 0x7370_696c_6c;

/// One demoted arena's payload set, keyed by packed `ObjectKey` word.
struct SpillSet {
    job: u64,
    objects: HashMap<u64, DataObj>,
    bytes: u64,
    /// When the set (last) started accruing storage-seconds.
    demoted_at: SimInstant,
}

/// The storage-seconds bill of one purged spill set.
#[derive(Clone, Debug, PartialEq)]
pub struct SpillSettlement {
    pub job: u64,
    /// Payload bytes the set held at purge time.
    pub bytes: u64,
    /// GB-seconds accrued between demotion and purge.
    pub gb_seconds: f64,
}

use crate::kvstore::netmodel::TailLatency;

/// The cold tier itself: per-arena spill sets, a seeded cold-read tail,
/// and cumulative demotion/read/billing meters. Owned by the cluster
/// ([`crate::kvstore::KvStore`]); one instance serves every job.
pub struct SpillTier {
    cfg: SpillConfig,
    /// Spill sets keyed by arena registration uid (unique per attach).
    sets: Mutex<HashMap<u64, SpillSet>>,
    /// Seeded heavy-tail stream for cold-read latency.
    tail: TailLatency,
    /// Cumulative payload bytes demoted into the tier.
    demoted_bytes: AtomicU64,
    /// Cumulative successful cold reads / bytes served.
    reads: AtomicU64,
    read_bytes: AtomicU64,
    /// Per-(uid, key) cold-read tallies; populated only while
    /// `promote_after_reads > 0` (the promotion-off path never locks in
    /// a tally).
    read_counts: Mutex<HashMap<(u64, u64), u32>>,
    /// Cumulative objects / payload bytes promoted back to the warm tier.
    promotions: AtomicU64,
    promoted_bytes: AtomicU64,
    /// GB-seconds already settled by purges.
    settled_gb_seconds: Mutex<f64>,
    /// Bills of sets deleted by the capacity cap, awaiting collection by
    /// [`SpillTier::purge_all`] (the service's settlement pass).
    pending_bills: Mutex<Vec<SpillSettlement>>,
    /// Cumulative payload bytes deleted by the capacity cap.
    cap_deleted_bytes: AtomicU64,
    /// Latest virtual instant any operation observed — the settlement
    /// timestamp for `Drop`-path purges that cannot query the clock.
    high_water: Mutex<SimInstant>,
}

impl SpillTier {
    pub fn new(cfg: SpillConfig, faults: &FaultConfig) -> Self {
        SpillTier {
            cfg,
            sets: Mutex::new(HashMap::new()),
            tail: TailLatency::from_faults(faults, SPILL_SALT),
            demoted_bytes: AtomicU64::new(0),
            reads: AtomicU64::new(0),
            read_bytes: AtomicU64::new(0),
            read_counts: Mutex::new(HashMap::new()),
            promotions: AtomicU64::new(0),
            promoted_bytes: AtomicU64::new(0),
            settled_gb_seconds: Mutex::new(0.0),
            pending_bills: Mutex::new(Vec::new()),
            cap_deleted_bytes: AtomicU64::new(0),
            high_water: Mutex::new(SimInstant::default()),
        }
    }

    /// Whether the tier accepts demotions.
    pub fn enabled(&self) -> bool {
        self.cfg.enabled
    }

    /// The tier's config (service report / billing rates).
    pub fn config(&self) -> &SpillConfig {
        &self.cfg
    }

    fn raise_high_water(&self, now: SimInstant) {
        let mut hw = self.high_water.lock().unwrap();
        if now > *hw {
            *hw = now;
        }
    }

    fn accrue(bytes: u64, from: SimInstant, to: SimInstant) -> f64 {
        bytes as f64 * 1e-9 * to.duration_since(from).as_secs_f64()
    }

    /// Parks an evicted arena's payload objects in the tier. Disabled
    /// tiers accept nothing (the caller destroys instead). Demotion is
    /// bookkeeping in virtual time — the cost model charges the *read*
    /// path — but the transferred bytes do count as network traffic
    /// (the caller feeds its `net_bytes_moved` ledger). Returns the
    /// bytes demoted.
    pub fn demote(
        &self,
        uid: u64,
        job: u64,
        objects: Vec<(u64, DataObj)>,
        now: SimInstant,
    ) -> u64 {
        if !self.cfg.enabled || objects.is_empty() {
            return 0;
        }
        self.raise_high_water(now);
        let mut sets = self.sets.lock().unwrap();
        let set = sets.entry(uid).or_insert_with(|| SpillSet {
            job,
            objects: HashMap::new(),
            bytes: 0,
            demoted_at: now,
        });
        // A re-demotion (defensive; eviction normally fires once per
        // arena) settles the accrual so far and restarts the meter.
        if set.bytes > 0 && set.demoted_at < now {
            *self.settled_gb_seconds.lock().unwrap() +=
                Self::accrue(set.bytes, set.demoted_at, now);
            set.demoted_at = now;
        }
        let mut added = 0u64;
        for (raw, obj) in objects {
            added += obj.bytes;
            if let Some(old) = set.objects.insert(raw, obj) {
                added -= old.bytes;
            }
        }
        set.bytes += added;
        self.demoted_bytes.fetch_add(added, Ordering::Relaxed);
        if self.cfg.max_spill_bytes < u64::MAX {
            self.enforce_cap(&mut sets, now);
        }
        added
    }

    /// Deletes oldest spill sets (smallest uid) until the parked total is
    /// at most `max_spill_bytes`, settling each victim's storage-seconds
    /// at `now` into the pending-bill queue. Called with the set map
    /// locked, from [`SpillTier::demote`] only — never on the uncapped
    /// default path.
    fn enforce_cap(&self, sets: &mut HashMap<u64, SpillSet>, now: SimInstant) {
        let mut total: u64 = sets.values().map(|s| s.bytes).sum();
        while total > self.cfg.max_spill_bytes {
            let oldest = sets.keys().copied().min().expect("total > 0 implies a set");
            let victim = sets.remove(&oldest).unwrap();
            total -= victim.bytes;
            let gb_seconds = Self::accrue(victim.bytes, victim.demoted_at, now);
            *self.settled_gb_seconds.lock().unwrap() += gb_seconds;
            self.cap_deleted_bytes.fetch_add(victim.bytes, Ordering::Relaxed);
            self.pending_bills.lock().unwrap().push(SpillSettlement {
                job: victim.job,
                bytes: victim.bytes,
                gb_seconds,
            });
        }
    }

    /// Looks up a demoted object (synchronous; the caller sleeps
    /// [`SpillTier::read_penalty`] before handing the bytes back).
    /// `None` when the tier is disabled or never held the object —
    /// the caller's `MissingObject` path is unchanged.
    pub fn read(&self, uid: u64, raw: u64, now: SimInstant) -> Option<DataObj> {
        if !self.cfg.enabled {
            return None;
        }
        let obj = self
            .sets
            .lock()
            .unwrap()
            .get(&uid)
            .and_then(|s| s.objects.get(&raw).cloned())?;
        self.raise_high_water(now);
        self.reads.fetch_add(1, Ordering::Relaxed);
        self.read_bytes.fetch_add(obj.bytes, Ordering::Relaxed);
        Some(obj)
    }

    /// [`SpillTier::read`] plus the promotion policy: the returned flag
    /// is `true` when this was the `promote_after_reads`-th cold read of
    /// the object and it has left the tier — the caller must re-insert
    /// the bytes into its warm arena (the object would otherwise be
    /// lost). With the knob at 0 this is byte-identical to `read`.
    pub fn read_promoting(&self, uid: u64, raw: u64, now: SimInstant) -> Option<(DataObj, bool)> {
        let obj = self.read(uid, raw, now)?;
        if self.cfg.promote_after_reads == 0 {
            return Some((obj, false));
        }
        {
            let mut counts = self.read_counts.lock().unwrap();
            let seen = counts.entry((uid, raw)).or_insert(0);
            *seen += 1;
            if *seen < self.cfg.promote_after_reads {
                return Some((obj, false));
            }
            counts.remove(&(uid, raw));
        }
        // Promote: drop the object from its set. The set's residency so
        // far settles at `now` into the pending-bill queue (attributed
        // to the owning job, like a cap deletion) and the meter restarts
        // at the reduced size, so billing still closes to zero.
        let mut sets = self.sets.lock().unwrap();
        let Some(set) = sets.get_mut(&uid) else {
            return Some((obj, false));
        };
        let Some(removed) = set.objects.remove(&raw) else {
            return Some((obj, false));
        };
        let gb_seconds = Self::accrue(set.bytes, set.demoted_at, now);
        *self.settled_gb_seconds.lock().unwrap() += gb_seconds;
        self.pending_bills.lock().unwrap().push(SpillSettlement {
            job: set.job,
            bytes: removed.bytes,
            gb_seconds,
        });
        set.demoted_at = now;
        set.bytes -= removed.bytes;
        if set.objects.is_empty() {
            sets.remove(&uid);
        }
        self.promotions.fetch_add(1, Ordering::Relaxed);
        self.promoted_bytes.fetch_add(removed.bytes, Ordering::Relaxed);
        Some((obj, true))
    }

    /// Free, synchronous existence probe — no metrics, no storage-second
    /// accrual. Used by the recovery watchdog's lineage walk, which must
    /// not recompute an intermediate that merely demoted to cold storage
    /// (and must not perturb billing while looking).
    pub fn peek(&self, uid: u64, raw: u64) -> bool {
        if !self.cfg.enabled {
            return false;
        }
        self.sets
            .lock()
            .unwrap()
            .get(&uid)
            .is_some_and(|s| s.objects.contains_key(&raw))
    }

    /// The virtual-time price of one cold read: seeded-tail request
    /// latency (S3 time-to-first-byte) plus streaming the payload at
    /// the tier's bandwidth.
    pub fn read_penalty(&self, bytes: u64) -> Duration {
        let latency = Duration::from_secs_f64(self.cfg.latency_ms.max(0.0) * 1e-3);
        let stream = Duration::from_secs_f64(bytes as f64 / self.cfg.bandwidth_bps.max(1.0));
        self.tail.sample(latency) + stream
    }

    /// Deletes one arena's spill set, settling its storage-seconds at
    /// `now`. Idempotent: a second purge finds nothing.
    pub fn purge(&self, uid: u64, now: SimInstant) -> Option<SpillSettlement> {
        let set = self.sets.lock().unwrap().remove(&uid)?;
        self.raise_high_water(now);
        let gb_seconds = Self::accrue(set.bytes, set.demoted_at, now);
        *self.settled_gb_seconds.lock().unwrap() += gb_seconds;
        Some(SpillSettlement {
            job: set.job,
            bytes: set.bytes,
            gb_seconds,
        })
    }

    /// `Drop`-path purge: settles at the tier's high-water mark because
    /// the caller may be outside the virtual-time executor (where the
    /// clock panics). Deterministic — the mark only ever advances via
    /// in-virtual-time operations.
    pub fn purge_at_high_water(&self, uid: u64) -> Option<SpillSettlement> {
        let now = *self.high_water.lock().unwrap();
        self.purge(uid, now)
    }

    /// End-of-run settlement: drains the cap-deletion bills accrued
    /// mid-run (in deletion order), then purges every remaining set in
    /// registration-uid order (deterministic), returning all the bills.
    pub fn purge_all(&self, now: SimInstant) -> Vec<SpillSettlement> {
        let mut bills = std::mem::take(&mut *self.pending_bills.lock().unwrap());
        let mut uids: Vec<u64> = self.sets.lock().unwrap().keys().copied().collect();
        uids.sort_unstable();
        bills.extend(uids.into_iter().filter_map(|uid| self.purge(uid, now)));
        bills
    }

    /// Payload bytes currently parked in the tier.
    pub fn live_bytes(&self) -> u64 {
        self.sets.lock().unwrap().values().map(|s| s.bytes).sum()
    }

    /// GB-seconds accrued by still-parked sets as of `now` (unsettled).
    /// Zero after a full purge — the billing-closes-to-zero invariant.
    pub fn live_gb_seconds(&self, now: SimInstant) -> f64 {
        self.sets
            .lock()
            .unwrap()
            .values()
            .map(|s| Self::accrue(s.bytes, s.demoted_at, now))
            .sum()
    }

    /// GB-seconds already settled by purges.
    pub fn settled_gb_seconds(&self) -> f64 {
        *self.settled_gb_seconds.lock().unwrap()
    }

    /// Cumulative payload bytes ever demoted into the tier.
    pub fn demoted_bytes(&self) -> u64 {
        self.demoted_bytes.load(Ordering::Relaxed)
    }

    /// Cumulative payload bytes deleted by the capacity cap (zero on the
    /// uncapped default).
    pub fn cap_deleted_bytes(&self) -> u64 {
        self.cap_deleted_bytes.load(Ordering::Relaxed)
    }

    /// Cumulative successful cold reads.
    pub fn reads(&self) -> u64 {
        self.reads.load(Ordering::Relaxed)
    }

    /// Cumulative payload bytes served by cold reads.
    pub fn read_bytes(&self) -> u64 {
        self.read_bytes.load(Ordering::Relaxed)
    }

    /// Cumulative objects promoted back to the warm tier (zero with the
    /// promotion knob off).
    pub fn promotions(&self) -> u64 {
        self.promotions.load(Ordering::Relaxed)
    }

    /// Cumulative payload bytes promoted back to the warm tier.
    pub fn promoted_bytes(&self) -> u64 {
        self.promoted_bytes.load(Ordering::Relaxed)
    }

    /// Dollars of storage-seconds settled so far.
    pub fn settled_cost_usd(&self) -> f64 {
        self.settled_gb_seconds() * self.cfg.cost_gb_s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tier(enabled: bool) -> SpillTier {
        SpillTier::new(
            SpillConfig {
                enabled,
                ..SpillConfig::default()
            },
            &FaultConfig::default(),
        )
    }

    fn at(secs: u64) -> SimInstant {
        SimInstant::default() + Duration::from_secs(secs)
    }

    #[test]
    fn disabled_tier_is_inert() {
        let t = tier(false);
        assert_eq!(
            t.demote(1, 7, vec![(0, DataObj::synthetic(100))], at(0)),
            0
        );
        assert!(t.read(1, 0, at(1)).is_none());
        assert!(t.purge_all(at(2)).is_empty());
        assert_eq!(t.demoted_bytes(), 0);
        assert_eq!(t.live_bytes(), 0);
    }

    #[test]
    fn demote_read_purge_roundtrip_and_storage_seconds() {
        let t = tier(true);
        let demoted = t.demote(
            1,
            7,
            vec![(10, DataObj::synthetic(4_000_000_000)), (11, DataObj::synthetic(0))],
            at(0),
        );
        assert_eq!(demoted, 4_000_000_000);
        assert_eq!(t.live_bytes(), 4_000_000_000);
        assert_eq!(t.read(1, 10, at(1)).unwrap().bytes, 4_000_000_000);
        assert!(t.read(1, 99, at(1)).is_none(), "never-stored key misses");
        assert!(t.read(2, 10, at(1)).is_none(), "foreign uid misses");
        assert_eq!(t.reads(), 1);
        assert_eq!(t.read_bytes(), 4_000_000_000);
        // 4 GB held for 10 s = 40 GB-seconds.
        assert!((t.live_gb_seconds(at(10)) - 40.0).abs() < 1e-9);
        let s = t.purge(1, at(10)).unwrap();
        assert_eq!(s.job, 7);
        assert_eq!(s.bytes, 4_000_000_000);
        assert!((s.gb_seconds - 40.0).abs() < 1e-9);
        assert!((t.settled_gb_seconds() - 40.0).abs() < 1e-9);
        assert_eq!(t.live_bytes(), 0);
        assert_eq!(t.live_gb_seconds(at(20)), 0.0, "billing closes to zero");
        assert!(t.purge(1, at(20)).is_none(), "purge is idempotent");
    }

    #[test]
    fn purge_all_settles_in_uid_order() {
        let t = tier(true);
        t.demote(5, 50, vec![(0, DataObj::synthetic(10))], at(0));
        t.demote(2, 20, vec![(0, DataObj::synthetic(20))], at(0));
        t.demote(9, 90, vec![(0, DataObj::synthetic(30))], at(0));
        let bills = t.purge_all(at(1));
        assert_eq!(
            bills.iter().map(|b| b.job).collect::<Vec<_>>(),
            vec![20, 50, 90]
        );
        assert_eq!(t.live_bytes(), 0);
    }

    #[test]
    fn high_water_settlement_matches_last_observed_instant() {
        let t = tier(true);
        t.demote(3, 30, vec![(0, DataObj::synthetic(2_000_000_000))], at(0));
        t.read(3, 0, at(5)); // advances the high-water mark
        let s = t.purge_at_high_water(3).unwrap();
        // 2 GB held 5 s (demote -> last read) = 10 GB-seconds.
        assert!((s.gb_seconds - 10.0).abs() < 1e-9, "{}", s.gb_seconds);
    }

    fn capped_tier(max_spill_bytes: u64) -> SpillTier {
        SpillTier::new(
            SpillConfig {
                enabled: true,
                max_spill_bytes,
                ..SpillConfig::default()
            },
            &FaultConfig::default(),
        )
    }

    #[test]
    fn cap_deletes_oldest_sets_and_bills_their_residency() {
        let t = capped_tier(150);
        t.demote(1, 10, vec![(0, DataObj::synthetic(100))], at(0));
        assert_eq!(t.live_bytes(), 100, "under cap: nothing deleted");
        // uid 2's demotion pushes the total to 200 > 150: uid 1 (oldest)
        // is deleted, settling 100 B held 0..5 s.
        t.demote(2, 20, vec![(0, DataObj::synthetic(100))], at(5));
        assert_eq!(t.live_bytes(), 100);
        assert_eq!(t.cap_deleted_bytes(), 100);
        assert!(t.read(1, 0, at(6)).is_none(), "deletion is real");
        assert!(!t.peek(1, 0));
        assert_eq!(t.read(2, 0, at(6)).unwrap().bytes, 100, "survivor serves");
        // The victim's bill reaches the settlement pass ahead of the
        // end-of-run purges, still attributed to its job.
        let bills = t.purge_all(at(10));
        assert_eq!(bills.len(), 2);
        assert_eq!(bills[0].job, 10);
        assert_eq!(bills[0].bytes, 100);
        assert!((bills[0].gb_seconds - 100.0 * 1e-9 * 5.0).abs() < 1e-18);
        assert_eq!(bills[1].job, 20);
        assert_eq!(t.live_bytes(), 0);
        assert_eq!(t.live_gb_seconds(at(20)), 0.0, "billing closes to zero");
    }

    #[test]
    fn cap_deletes_a_set_too_large_to_ever_fit() {
        let t = capped_tier(50);
        t.demote(7, 70, vec![(0, DataObj::synthetic(100))], at(0));
        assert_eq!(t.live_bytes(), 0, "oversized set is its own victim");
        assert_eq!(t.cap_deleted_bytes(), 100);
        assert!(t.read(7, 0, at(1)).is_none());
        assert_eq!(t.purge_all(at(1)).len(), 1, "it is still billed");
    }

    #[test]
    fn uncapped_default_never_deletes() {
        let t = tier(true); // max_spill_bytes = u64::MAX
        for uid in 0..8u64 {
            t.demote(uid, uid, vec![(0, DataObj::synthetic(u32::MAX as u64))], at(0));
        }
        assert_eq!(t.cap_deleted_bytes(), 0);
        assert_eq!(t.live_bytes(), 8 * (u32::MAX as u64));
        assert_eq!(t.purge_all(at(1)).len(), 8);
    }

    fn promoting_tier(promote_after_reads: u32) -> SpillTier {
        SpillTier::new(
            SpillConfig {
                enabled: true,
                promote_after_reads,
                ..SpillConfig::default()
            },
            &FaultConfig::default(),
        )
    }

    #[test]
    fn promotion_off_read_promoting_is_identical_to_read() {
        let t = tier(true); // promote_after_reads = 0
        t.demote(1, 7, vec![(0, DataObj::synthetic(100))], at(0));
        for _ in 0..10 {
            let (obj, promoted) = t.read_promoting(1, 0, at(1)).unwrap();
            assert_eq!(obj.bytes, 100);
            assert!(!promoted, "knob at 0 never promotes");
        }
        assert_eq!(t.promotions(), 0);
        assert_eq!(t.reads(), 10);
        assert_eq!(t.live_bytes(), 100, "object never leaves the tier");
    }

    #[test]
    fn nth_cold_read_promotes_and_settles_residency() {
        let t = promoting_tier(3);
        t.demote(
            1,
            7,
            vec![(10, DataObj::synthetic(2_000_000_000)), (11, DataObj::synthetic(50))],
            at(0),
        );
        assert!(!t.read_promoting(1, 10, at(2)).unwrap().1);
        assert!(!t.read_promoting(1, 10, at(4)).unwrap().1);
        // Third read of key 10 promotes it; key 11 stays parked.
        let (obj, promoted) = t.read_promoting(1, 10, at(10)).unwrap();
        assert!(promoted);
        assert_eq!(obj.bytes, 2_000_000_000);
        assert_eq!(t.promotions(), 1);
        assert_eq!(t.promoted_bytes(), 2_000_000_000);
        assert_eq!(t.live_bytes(), 50);
        assert!(t.read(1, 10, at(11)).is_none(), "promotion is real");
        assert!(t.peek(1, 11), "sibling object survives");
        // The whole set's residency 0..10 s settled at promotion and the
        // remainder accrues from the promotion instant — billing still
        // closes to zero: ~2 GB * 10 s = 20.0000005 GB-s settled.
        let expected = (2_000_000_050u64 as f64) * 1e-9 * 10.0;
        assert!((t.settled_gb_seconds() - expected).abs() < 1e-9);
        let bills = t.purge_all(at(20));
        assert_eq!(bills.len(), 2, "promotion bill + end-of-run purge");
        assert_eq!(bills[0].job, 7);
        assert_eq!(bills[0].bytes, 2_000_000_000);
        assert_eq!(bills[1].bytes, 50);
        assert!((bills[1].gb_seconds - 50.0 * 1e-9 * 10.0).abs() < 1e-18);
        assert_eq!(t.live_gb_seconds(at(30)), 0.0, "billing closes to zero");
    }

    #[test]
    fn fully_promoted_set_leaves_no_residue() {
        let t = promoting_tier(1);
        t.demote(4, 40, vec![(0, DataObj::synthetic(100))], at(0));
        let (_, promoted) = t.read_promoting(4, 0, at(5)).unwrap();
        assert!(promoted, "first read promotes at threshold 1");
        assert_eq!(t.live_bytes(), 0);
        assert_eq!(t.purge_all(at(10)).len(), 1, "only the promotion bill");
        assert_eq!(t.live_gb_seconds(at(10)), 0.0);
    }

    #[test]
    fn read_penalty_charges_latency_plus_stream() {
        let t = SpillTier::new(
            SpillConfig {
                enabled: true,
                latency_ms: 15.0,
                bandwidth_bps: 90e6,
                ..SpillConfig::default()
            },
            &FaultConfig::default(),
        );
        let p = t.read_penalty(90_000_000);
        // 15 ms TTFB + 1 s streaming 90 MB at 90 MB/s.
        assert_eq!(p, Duration::from_millis(15) + Duration::from_secs(1));
        assert_eq!(t.read_penalty(0), Duration::from_millis(15));
    }
}
