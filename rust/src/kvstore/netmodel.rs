//! Network cost model.
//!
//! Each endpoint NIC is modeled as a FIFO bandwidth server: a transfer
//! queues for the NIC, holds it for `bytes / bandwidth`, then releases it.
//! Queueing delay under burst load emerges naturally — this is what
//! produces the heavy upper tail of KV latencies in Fig. 13 (a minority of
//! tasks saw 10 s+ reads/writes when hundreds of Lambdas hit the shards at
//! once) and the resource-contention effect of co-locating all shards on
//! one VM (Fig. 12's "shard per VM" factor).

use crate::core::{clock, FaultConfig, SplitMix64};
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// Seeded heavy-tail latency model: each sampled operation independently
/// hits the tail with probability `prob`, multiplying its base latency by
/// `factor`. Draws come from one `SplitMix64` stream, so — on the
/// deterministic single-threaded runtime — identical runs sample identical
/// tails. This is the fault-injection form of the latency upper tail the
/// paper observed when hundreds of Lambdas hit the KV shards at once
/// (Fig. 13).
pub struct TailLatency {
    prob: f64,
    factor: f64,
    rng: Mutex<SplitMix64>,
}

impl std::fmt::Debug for TailLatency {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "TailLatency(p={}, x{})", self.prob, self.factor)
    }
}

impl TailLatency {
    /// Builds the KV tail model of a fault profile. A benign profile
    /// yields a pass-through model (every sample returns the base).
    pub fn from_faults(faults: &FaultConfig, stream_salt: u64) -> Self {
        TailLatency {
            prob: faults.kv_tail_prob,
            factor: faults.kv_tail_factor.max(1.0),
            rng: Mutex::new(SplitMix64::new(
                faults.seed ^ stream_salt.wrapping_mul(0x9E37_79B9_7F4A_7C15),
            )),
        }
    }

    /// Samples the latency of one operation with base latency `base`.
    pub fn sample(&self, base: Duration) -> Duration {
        if self.prob <= 0.0 || self.factor <= 1.0 || base.is_zero() {
            return base;
        }
        let hit = self.rng.lock().unwrap().next_f64() < self.prob;
        if hit {
            base.mul_f64(self.factor)
        } else {
            base
        }
    }
}

/// A FIFO bandwidth server (one NIC / one network direction).
pub struct Nic {
    bytes_per_sec: f64,
    queue: crate::rt::sync::Mutex<()>,
}

impl std::fmt::Debug for Nic {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "Nic({} B/s)", self.bytes_per_sec)
    }
}

impl Nic {
    pub fn new(bytes_per_sec: f64) -> Arc<Self> {
        assert!(bytes_per_sec > 0.0);
        Arc::new(Nic {
            bytes_per_sec,
            queue: crate::rt::sync::Mutex::new(()),
        })
    }

    /// Pure service time of `bytes` at this NIC's bandwidth (no queueing).
    pub fn service_time(&self, bytes: u64) -> Duration {
        Duration::from_secs_f64(bytes as f64 / self.bytes_per_sec)
    }

    /// Occupies the NIC for the service time of `bytes` (the rt mutex
    /// is FIFO-fair). Zero-byte transfers don't queue.
    pub async fn transfer(&self, bytes: u64) {
        if bytes == 0 {
            return;
        }
        let _guard = self.queue.lock().await;
        clock::sleep(self.service_time(bytes)).await;
    }

    /// Transfer limited by *two* endpoints: this NIC (queued) and a slower
    /// remote link (not queued — a Lambda's private NIC serves only its own
    /// traffic). Total time = max of the two service times, with only the
    /// local part holding this NIC.
    pub async fn transfer_capped(&self, bytes: u64, remote_bps: f64) {
        if bytes == 0 {
            return;
        }
        let local = self.service_time(bytes);
        let total = Duration::from_secs_f64(bytes as f64 / remote_bps.min(self.bytes_per_sec));
        {
            let _guard = self.queue.lock().await;
            clock::sleep(local).await;
        }
        if total > local {
            clock::sleep(total - local).await;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::clock::now;

    #[test]
    fn service_time_is_bytes_over_bw() {
        crate::rt::run_virtual(async {
            let nic = Nic::new(1000.0); // 1000 B/s
            let t0 = now();
            nic.transfer(500).await;
            assert_eq!(now() - t0, Duration::from_millis(500));
        });
    }

    #[test]
    fn concurrent_transfers_queue() {
        crate::rt::run_virtual(async {
            let nic = Nic::new(1000.0);
            let t0 = now();
            let a = crate::rt::spawn({
                let nic = nic.clone();
                async move { nic.transfer(500).await }
            });
            let b = crate::rt::spawn({
                let nic = nic.clone();
                async move { nic.transfer(500).await }
            });
            a.await;
            b.await;
            // FIFO: the two transfers serialize -> 1s total, not 0.5s.
            assert_eq!(now() - t0, Duration::from_secs(1));
        });
    }

    #[test]
    fn capped_transfer_respects_slow_remote() {
        crate::rt::run_virtual(async {
            let nic = Nic::new(10_000.0);
            let t0 = now();
            nic.transfer_capped(1000, 1000.0).await; // remote is 10x slower
            assert_eq!(now() - t0, Duration::from_secs(1));
        });
    }

    #[test]
    fn tail_latency_benign_passthrough() {
        let t = TailLatency::from_faults(&FaultConfig::default(), 1);
        let base = Duration::from_micros(300);
        for _ in 0..100 {
            assert_eq!(t.sample(base), base);
        }
    }

    #[test]
    fn tail_latency_deterministic_and_bounded() {
        let mk = || {
            TailLatency::from_faults(
                &FaultConfig {
                    kv_tail_prob: 0.2,
                    kv_tail_factor: 10.0,
                    seed: 42,
                    ..FaultConfig::default()
                },
                3,
            )
        };
        let (a, b) = (mk(), mk());
        let base = Duration::from_micros(300);
        let mut tails = 0;
        for _ in 0..1000 {
            let (sa, sb) = (a.sample(base), b.sample(base));
            assert_eq!(sa, sb, "same seed must sample identically");
            assert!(sa == base || sa == base.mul_f64(10.0));
            if sa > base {
                tails += 1;
            }
        }
        assert!((100..400).contains(&tails), "tail rate ~20%, got {tails}");
    }

    #[test]
    fn zero_bytes_is_free() {
        crate::rt::run_virtual(async {
            let nic = Nic::new(1.0);
            let t0 = now();
            nic.transfer(0).await;
            assert_eq!(now(), t0);
        });
    }
}
