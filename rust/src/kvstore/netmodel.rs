//! Network cost model.
//!
//! Each endpoint NIC is modeled as a FIFO bandwidth server: a transfer
//! queues for the NIC, holds it for `bytes / bandwidth`, then releases it.
//! Queueing delay under burst load emerges naturally — this is what
//! produces the heavy upper tail of KV latencies in Fig. 13 (a minority of
//! tasks saw 10 s+ reads/writes when hundreds of Lambdas hit the shards at
//! once) and the resource-contention effect of co-locating all shards on
//! one VM (Fig. 12's "shard per VM" factor).

use crate::core::clock;
use std::sync::Arc;
use std::time::Duration;

/// A FIFO bandwidth server (one NIC / one network direction).
pub struct Nic {
    bytes_per_sec: f64,
    queue: crate::rt::sync::Mutex<()>,
}

impl std::fmt::Debug for Nic {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "Nic({} B/s)", self.bytes_per_sec)
    }
}

impl Nic {
    pub fn new(bytes_per_sec: f64) -> Arc<Self> {
        assert!(bytes_per_sec > 0.0);
        Arc::new(Nic {
            bytes_per_sec,
            queue: crate::rt::sync::Mutex::new(()),
        })
    }

    /// Pure service time of `bytes` at this NIC's bandwidth (no queueing).
    pub fn service_time(&self, bytes: u64) -> Duration {
        Duration::from_secs_f64(bytes as f64 / self.bytes_per_sec)
    }

    /// Occupies the NIC for the service time of `bytes` (the rt mutex
    /// is FIFO-fair). Zero-byte transfers don't queue.
    pub async fn transfer(&self, bytes: u64) {
        if bytes == 0 {
            return;
        }
        let _guard = self.queue.lock().await;
        clock::sleep(self.service_time(bytes)).await;
    }

    /// Transfer limited by *two* endpoints: this NIC (queued) and a slower
    /// remote link (not queued — a Lambda's private NIC serves only its own
    /// traffic). Total time = max of the two service times, with only the
    /// local part holding this NIC.
    pub async fn transfer_capped(&self, bytes: u64, remote_bps: f64) {
        if bytes == 0 {
            return;
        }
        let local = self.service_time(bytes);
        let total = Duration::from_secs_f64(bytes as f64 / remote_bps.min(self.bytes_per_sec));
        {
            let _guard = self.queue.lock().await;
            clock::sleep(local).await;
        }
        if total > local {
            clock::sleep(total - local).await;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::clock::now;

    #[test]
    fn service_time_is_bytes_over_bw() {
        crate::rt::run_virtual(async {
            let nic = Nic::new(1000.0); // 1000 B/s
            let t0 = now();
            nic.transfer(500).await;
            assert_eq!(now() - t0, Duration::from_millis(500));
        });
    }

    #[test]
    fn concurrent_transfers_queue() {
        crate::rt::run_virtual(async {
            let nic = Nic::new(1000.0);
            let t0 = now();
            let a = crate::rt::spawn({
                let nic = nic.clone();
                async move { nic.transfer(500).await }
            });
            let b = crate::rt::spawn({
                let nic = nic.clone();
                async move { nic.transfer(500).await }
            });
            a.await;
            b.await;
            // FIFO: the two transfers serialize -> 1s total, not 0.5s.
            assert_eq!(now() - t0, Duration::from_secs(1));
        });
    }

    #[test]
    fn capped_transfer_respects_slow_remote() {
        crate::rt::run_virtual(async {
            let nic = Nic::new(10_000.0);
            let t0 = now();
            nic.transfer_capped(1000, 1000.0).await; // remote is 10x slower
            assert_eq!(now() - t0, Duration::from_secs(1));
        });
    }

    #[test]
    fn zero_bytes_is_free() {
        crate::rt::run_virtual(async {
            let nic = Nic::new(1.0);
            let t0 = now();
            nic.transfer(0).await;
            assert_eq!(now(), t0);
        });
    }
}
