//! Network cost model.
//!
//! Each endpoint NIC is modeled as a serial bandwidth server: a transfer
//! queues for the NIC, holds it for `bytes / bandwidth`, then releases it.
//! Queueing delay under burst load emerges naturally — this is what
//! produces the heavy upper tail of KV latencies in Fig. 13 (a minority of
//! tasks saw 10 s+ reads/writes when hundreds of Lambdas hit the shards at
//! once) and the resource-contention effect of co-locating all shards on
//! one VM (Fig. 12's "shard per VM" factor).
//!
//! ## Cross-job fairness (deficit round robin)
//!
//! The service discipline is per-job **deficit-round-robin** (DRR)
//! virtual-time queueing: each job with pending transfers owns a FIFO
//! queue, and the NIC visits the queues round-robin, granting each visit
//! a byte *quantum*; a queue's head is served once its accumulated
//! deficit covers the head's size. A 1M-task tenant flooding a shard NIC
//! can therefore no longer head-of-line-block an 8-task tenant — the
//! light tenant's transfer is served within roughly one rotation instead
//! of behind the heavy tenant's entire backlog.
//!
//! Two properties are pinned by tests:
//!
//! * **Solo runs are FIFO-identical.** With a single job on the NIC the
//!   scheduler grants strictly in arrival order regardless of the
//!   quantum, so `JobId(0)`-solo timing is bit-identical to the old FIFO
//!   queue (the pre-governance engine).
//! * **FIFO is still available** (`Nic::with_queueing(.., fair=false, ..)`
//!   / `NetConfig::nic_fair_queueing = false`): all jobs collapse into
//!   one queue — the before/after arm of the `nic/fifo-hog` vs
//!   `nic/drr-hog` bench pair.
//!
//! Quanta are **class-weighted** ([`Nic::set_job_weight`], plumbed from
//! `NetConfig::nic_drr_class_weights` by tenant): a job with weight `w`
//! earns `w * quantum` bytes of credit per visit, so a premium class's
//! oversized transfers clear in proportionally fewer rotations. Weight 1
//! (the default for every unconfigured job) is bit-identical to the
//! unweighted discipline, and solo-job timing is weight-independent.
//!
//! The model holds the NIC by sleeping through `clock::sleep`, which
//! makes it time-source-agnostic: the serial-bandwidth server and its
//! DRR rotation run unchanged whether the executor clock is the
//! deterministic `VirtualTime` source or the wall-clock `WallTime`
//! source behind the `serve` front door.

use crate::core::{clock, FaultConfig, JobId, SplitMix64};
use std::collections::{HashMap, VecDeque};
use std::future::Future;
use std::pin::Pin;
use std::sync::{Arc, Mutex};
use std::task::{Context, Poll, Waker};
use std::time::Duration;

/// Seeded heavy-tail latency model: each sampled operation independently
/// hits the tail with probability `prob`, multiplying its base latency by
/// `factor`. Draws come from one `SplitMix64` stream, so — on the
/// deterministic single-threaded runtime — identical runs sample identical
/// tails. This is the fault-injection form of the latency upper tail the
/// paper observed when hundreds of Lambdas hit the KV shards at once
/// (Fig. 13).
pub struct TailLatency {
    prob: f64,
    factor: f64,
    rng: Mutex<SplitMix64>,
}

impl std::fmt::Debug for TailLatency {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "TailLatency(p={}, x{})", self.prob, self.factor)
    }
}

impl TailLatency {
    /// Builds the KV tail model of a fault profile. A benign profile
    /// yields a pass-through model (every sample returns the base).
    pub fn from_faults(faults: &FaultConfig, stream_salt: u64) -> Self {
        TailLatency {
            prob: faults.kv_tail_prob,
            factor: faults.kv_tail_factor.max(1.0),
            rng: Mutex::new(SplitMix64::new(
                faults.seed ^ stream_salt.wrapping_mul(0x9E37_79B9_7F4A_7C15),
            )),
        }
    }

    /// Samples the latency of one operation with base latency `base`.
    pub fn sample(&self, base: Duration) -> Duration {
        if self.prob <= 0.0 || self.factor <= 1.0 || base.is_zero() {
            return base;
        }
        let hit = self.rng.lock().unwrap().next_f64() < self.prob;
        if hit {
            base.mul_f64(self.factor)
        } else {
            base
        }
    }
}

/// Default DRR byte quantum: one rotation grants each contending job up
/// to 64 KiB of service credit. Small enough that a light tenant's small
/// messages interleave with a heavy tenant's bulk transfers, large enough
/// that typical task outputs are served in one or two visits.
pub const DEFAULT_NIC_QUANTUM: u64 = 64 * 1024;

/// One transfer waiting for the NIC.
struct NicWaiter {
    bytes: u64,
    waker: Option<Waker>,
    /// Set by the dispatcher when this waiter is handed the NIC. From
    /// that point the waiter (or its `Drop`) owns the release.
    granted: bool,
    /// Virtual time on the dispatching shard's clock at grant (None when
    /// granted outside an executor). Under sharded simulation the woken
    /// waiter re-sleeps to this stamp so it starts its service at exactly
    /// the serial run's instant.
    granted_at: Option<clock::SimInstant>,
}

/// Scheduler state of one NIC (plain mutex: critical sections never
/// await, and the virtual-time runtime is single-threaded).
struct NicState {
    /// True while some transfer holds the NIC (or has been granted it and
    /// not yet released).
    busy: bool,
    next_waiter: u64,
    /// Waiter id -> waiter. An id missing from this map but still present
    /// in a queue is a cancelled transfer (pruned at dispatch).
    waiters: HashMap<u64, NicWaiter>,
    /// Per-job FIFO queues of waiter ids. An entry exists iff the job has
    /// at least one (possibly cancelled) queued waiter.
    queues: HashMap<u64, VecDeque<u64>>,
    /// Round-robin ring of jobs with queued transfers, in first-arrival
    /// order. Invariant: `rr` contains exactly the keys of `queues`.
    rr: VecDeque<u64>,
    /// DRR deficit counters, reset when a job's queue drains (no banking
    /// of idle credit).
    deficit: HashMap<u64, u64>,
    /// Per-job DRR weight multipliers (tenant-class weighting): a job
    /// with weight `w` earns `w * quantum` bytes of credit per visit.
    /// Absent entries weigh 1, so an unconfigured NIC is bit-identical
    /// to the unweighted discipline. Keyed by `JobId.0` (ignored under
    /// FIFO collapse). Solo-job service is weight-independent by
    /// construction (the sole-queue path zeroes the deficit).
    weights: HashMap<u64, u64>,
}

/// A serial bandwidth server (one NIC / one network direction) with
/// per-job DRR fair queueing (or plain FIFO — see [`Nic::with_queueing`]).
pub struct Nic {
    bytes_per_sec: f64,
    /// DRR byte quantum granted per queue visit (`>= 1`).
    quantum: u64,
    /// When false, every job maps to one shared queue — the legacy FIFO
    /// discipline, kept for the fairness before/after bench pair.
    fair: bool,
    state: Mutex<NicState>,
}

impl std::fmt::Debug for Nic {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "Nic({} B/s, {})",
            self.bytes_per_sec,
            if self.fair { "drr" } else { "fifo" }
        )
    }
}

/// RAII ownership of the NIC for one transfer's service time; dropping it
/// dispatches the next queued transfer (so a cancelled transfer — e.g. a
/// function timeout firing mid-service — can never wedge the NIC).
struct NicPermit<'a> {
    nic: &'a Nic,
}

impl Drop for NicPermit<'_> {
    fn drop(&mut self) {
        self.nic.dispatch_next();
    }
}

/// Future acquiring the NIC for a `(job, bytes)` transfer under the DRR
/// discipline. Cancellation-safe: dropping it while queued removes the
/// waiter; dropping it after a grant it never observed releases the NIC.
struct Acquire<'a> {
    nic: &'a Nic,
    job: u64,
    bytes: u64,
    id: Option<u64>,
    acquired: bool,
    /// Coordinator hold while queued cross-shard (None in serial runs or
    /// once the grant has been observed).
    hold: Option<crate::rt::sharded::HoldGuard>,
}

impl<'a> Future for Acquire<'a> {
    type Output = NicPermit<'a>;

    fn poll(mut self: Pin<&mut Self>, cx: &mut Context<'_>) -> Poll<Self::Output> {
        let this = &mut *self;
        match this.id {
            None => {
                // Entry is a sharded sequence point: after admission no
                // other live shard can act at an earlier virtual time, so
                // the busy check and FIFO enqueue below land in
                // virtual-time order fleet-wide (no-op in serial runs).
                let _gate = crate::rt::sharded::gate();
                let mut s = this.nic.state.lock().unwrap();
                if !s.busy {
                    // Idle NIC: the invariantly-empty queues mean nobody
                    // is ahead of us — serve immediately.
                    s.busy = true;
                    this.acquired = true;
                    return Poll::Ready(NicPermit { nic: this.nic });
                }
                let id = s.next_waiter;
                s.next_waiter += 1;
                this.id = Some(id);
                s.waiters.insert(
                    id,
                    NicWaiter {
                        bytes: this.bytes,
                        waker: Some(cx.waker().clone()),
                        granted: false,
                        granted_at: None,
                    },
                );
                match s.queues.entry(this.job) {
                    std::collections::hash_map::Entry::Vacant(e) => {
                        e.insert(VecDeque::from([id]));
                        s.rr.push_back(this.job);
                    }
                    std::collections::hash_map::Entry::Occupied(mut e) => {
                        e.get_mut().push_back(id);
                    }
                }
                drop(s);
                this.hold = crate::rt::sharded::hold();
                Poll::Pending
            }
            Some(id) => {
                let mut s = this.nic.state.lock().unwrap();
                let w = s.waiters.get_mut(&id).expect("live waiter");
                if w.granted {
                    let stamp = w.granted_at;
                    drop(s);
                    // The rendezvous has resolved: the remaining wait (if
                    // any) is a local timer to the grant's virtual-time
                    // stamp; the shard's advance needs no further cap.
                    this.hold = None;
                    if let Some(stamp) = stamp {
                        if crate::rt::time::poll_sleep_until(stamp, cx).is_pending() {
                            // The waiter stays in the map as granted, so a
                            // drop mid-stamp-sleep still releases the NIC.
                            return Poll::Pending;
                        }
                    }
                    this.nic.state.lock().unwrap().waiters.remove(&id);
                    this.acquired = true;
                    Poll::Ready(NicPermit { nic: this.nic })
                } else {
                    w.waker = Some(cx.waker().clone());
                    Poll::Pending
                }
            }
        }
    }
}

impl Drop for Acquire<'_> {
    fn drop(&mut self) {
        if self.acquired {
            return; // the permit owns the release now
        }
        let Some(id) = self.id else {
            return; // never enqueued
        };
        let granted = {
            let mut s = self.nic.state.lock().unwrap();
            match s.waiters.remove(&id) {
                // Still queued: the stale id left in the queue is pruned
                // at the next dispatch.
                Some(w) => w.granted,
                None => false,
            }
        };
        if granted {
            // Granted but cancelled before observing it: we own the NIC.
            self.nic.dispatch_next();
        }
    }
}

impl Nic {
    /// A DRR fair-queueing NIC with the default quantum.
    pub fn new(bytes_per_sec: f64) -> Arc<Self> {
        Self::with_queueing(bytes_per_sec, true, DEFAULT_NIC_QUANTUM)
    }

    /// Full constructor: `fair = false` collapses every job into one
    /// FIFO queue (the pre-governance discipline); `quantum_bytes` is the
    /// DRR byte credit granted per queue visit.
    pub fn with_queueing(bytes_per_sec: f64, fair: bool, quantum_bytes: u64) -> Arc<Self> {
        assert!(bytes_per_sec > 0.0);
        Arc::new(Nic {
            bytes_per_sec,
            quantum: quantum_bytes.max(1),
            fair,
            state: Mutex::new(NicState {
                busy: false,
                next_waiter: 0,
                waiters: HashMap::new(),
                queues: HashMap::new(),
                rr: VecDeque::new(),
                deficit: HashMap::new(),
                weights: HashMap::new(),
            }),
        })
    }

    /// Sets `job`'s DRR weight multiplier: `weight * quantum` bytes of
    /// credit per queue visit (class-weighted fair queueing). Weight 1 —
    /// or never calling this — is the unweighted discipline. No effect
    /// under FIFO collapse (`fair = false`) or on a solo job.
    pub fn set_job_weight(&self, job: JobId, weight: u64) {
        let mut s = self.state.lock().unwrap();
        if weight <= 1 {
            s.weights.remove(&job.0);
        } else {
            s.weights.insert(job.0, weight);
        }
    }

    /// Drops `job`'s DRR weight (back to 1). Called at job retirement so
    /// a long-running service does not accumulate dead entries.
    pub fn clear_job_weight(&self, job: JobId) {
        self.state.lock().unwrap().weights.remove(&job.0);
    }

    /// Pure service time of `bytes` at this NIC's bandwidth (no queueing).
    pub fn service_time(&self, bytes: u64) -> Duration {
        Duration::from_secs_f64(bytes as f64 / self.bytes_per_sec)
    }

    fn queue_key(&self, job: JobId) -> u64 {
        if self.fair {
            job.0
        } else {
            0
        }
    }

    /// Hands the NIC to the next queued transfer per the DRR discipline,
    /// or marks it idle. Called whenever the current holder releases.
    fn dispatch_next(&self) {
        // Release reorders the queue's future: a sharded sequence point,
        // so cross-shard releases and enqueues interleave in virtual-time
        // order (no-op guard in serial runs). The grant below is stamped
        // with this shard's clock.
        let _gate = crate::rt::sharded::gate();
        let mut s = self.state.lock().unwrap();
        loop {
            let Some(j) = s.rr.pop_front() else {
                s.busy = false;
                return;
            };
            // Prune cancelled waiters off the head of j's queue.
            loop {
                let Some(&head) = s.queues.get(&j).and_then(|q| q.front()) else {
                    break;
                };
                if s.waiters.contains_key(&head) {
                    break;
                }
                s.queues.get_mut(&j).unwrap().pop_front();
            }
            if s.queues.get(&j).is_none_or(|q| q.is_empty()) {
                s.queues.remove(&j);
                s.deficit.remove(&j); // queue drained: no banked credit
                continue;
            }
            let head = *s.queues.get(&j).unwrap().front().unwrap();
            let need = s.waiters.get(&head).expect("head is live").bytes;
            let sole = s.rr.is_empty();
            let credit = self
                .quantum
                .saturating_mul(*s.weights.get(&j).unwrap_or(&1))
                .max(1);
            let d = s.deficit.entry(j).or_insert(0);
            *d = d.saturating_add(credit);
            if sole {
                // No competing job: pure FIFO, and idle credit must not
                // bank up for later contention.
                *d = 0;
            } else if *d < need {
                // Not enough credit yet — next job's turn; the deficit
                // persists and grows on the next visit.
                s.rr.push_back(j);
                continue;
            } else {
                *d -= need;
            }
            s.queues.get_mut(&j).unwrap().pop_front();
            if s.queues.get(&j).unwrap().is_empty() {
                s.queues.remove(&j);
                s.deficit.remove(&j);
            } else {
                s.rr.push_back(j);
            }
            let w = s.waiters.get_mut(&head).expect("head is live");
            w.granted = true;
            w.granted_at = clock::try_now();
            if let Some(wk) = w.waker.take() {
                wk.wake();
            }
            // `busy` stays true: the grantee owns the NIC.
            return;
        }
    }

    fn acquire(&self, job: JobId, bytes: u64) -> Acquire<'_> {
        Acquire {
            nic: self,
            job: self.queue_key(job),
            bytes,
            id: None,
            acquired: false,
            hold: None,
        }
    }

    /// Occupies the NIC for the service time of `bytes` on behalf of
    /// `job` (DRR across jobs, FIFO within one). Zero-byte transfers
    /// don't queue.
    pub async fn transfer_as(&self, job: JobId, bytes: u64) {
        if bytes == 0 {
            return;
        }
        let permit = self.acquire(job, bytes).await;
        clock::sleep(self.service_time(bytes)).await;
        drop(permit);
    }

    /// [`Nic::transfer_as`] for single-job callers (`JobId(0)`).
    pub async fn transfer(&self, bytes: u64) {
        self.transfer_as(JobId(0), bytes).await;
    }

    /// Transfer limited by *two* endpoints: this NIC (queued) and a slower
    /// remote link (not queued — a Lambda's private NIC serves only its own
    /// traffic). Total time = max of the two service times, with only the
    /// local part holding this NIC.
    pub async fn transfer_capped_as(&self, job: JobId, bytes: u64, remote_bps: f64) {
        if bytes == 0 {
            return;
        }
        let local = self.service_time(bytes);
        let total = Duration::from_secs_f64(bytes as f64 / remote_bps.min(self.bytes_per_sec));
        {
            let permit = self.acquire(job, bytes).await;
            clock::sleep(local).await;
            drop(permit);
        }
        if total > local {
            clock::sleep(total - local).await;
        }
    }

    /// [`Nic::transfer_capped_as`] for single-job callers (`JobId(0)`).
    pub async fn transfer_capped(&self, bytes: u64, remote_bps: f64) {
        self.transfer_capped_as(JobId(0), bytes, remote_bps).await;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::clock::now;

    #[test]
    fn service_time_is_bytes_over_bw() {
        crate::rt::run_virtual(async {
            let nic = Nic::new(1000.0); // 1000 B/s
            let t0 = now();
            nic.transfer(500).await;
            assert_eq!(now() - t0, Duration::from_millis(500));
        });
    }

    #[test]
    fn concurrent_transfers_queue() {
        crate::rt::run_virtual(async {
            let nic = Nic::new(1000.0);
            let t0 = now();
            let a = crate::rt::spawn({
                let nic = nic.clone();
                async move { nic.transfer(500).await }
            });
            let b = crate::rt::spawn({
                let nic = nic.clone();
                async move { nic.transfer(500).await }
            });
            a.await;
            b.await;
            // Same job: the two transfers serialize -> 1s total, not 0.5s.
            assert_eq!(now() - t0, Duration::from_secs(1));
        });
    }

    #[test]
    fn capped_transfer_respects_slow_remote() {
        crate::rt::run_virtual(async {
            let nic = Nic::new(10_000.0);
            let t0 = now();
            nic.transfer_capped(1000, 1000.0).await; // remote is 10x slower
            assert_eq!(now() - t0, Duration::from_secs(1));
        });
    }

    #[test]
    fn tail_latency_benign_passthrough() {
        let t = TailLatency::from_faults(&FaultConfig::default(), 1);
        let base = Duration::from_micros(300);
        for _ in 0..100 {
            assert_eq!(t.sample(base), base);
        }
    }

    #[test]
    fn tail_latency_deterministic_and_bounded() {
        let mk = || {
            TailLatency::from_faults(
                &FaultConfig {
                    kv_tail_prob: 0.2,
                    kv_tail_factor: 10.0,
                    seed: 42,
                    ..FaultConfig::default()
                },
                3,
            )
        };
        let (a, b) = (mk(), mk());
        let base = Duration::from_micros(300);
        let mut tails = 0;
        for _ in 0..1000 {
            let (sa, sb) = (a.sample(base), b.sample(base));
            assert_eq!(sa, sb, "same seed must sample identically");
            assert!(sa == base || sa == base.mul_f64(10.0));
            if sa > base {
                tails += 1;
            }
        }
        assert!((100..400).contains(&tails), "tail rate ~20%, got {tails}");
    }

    #[test]
    fn zero_bytes_is_free() {
        crate::rt::run_virtual(async {
            let nic = Nic::new(1.0);
            let t0 = now();
            nic.transfer(0).await;
            assert_eq!(now(), t0);
        });
    }

    /// Runs `hog` back-to-back transfers of job 1 queued ahead of one
    /// small job-2 transfer; returns (light completion, total makespan).
    fn hog_scenario(fair: bool, hog: usize) -> (Duration, Duration) {
        crate::rt::run_virtual(async move {
            let nic = Nic::with_queueing(1e6, fair, DEFAULT_NIC_QUANTUM);
            let t0 = now();
            let mut hogs = Vec::with_capacity(hog);
            for _ in 0..hog {
                let nic = nic.clone();
                hogs.push(crate::rt::spawn(async move {
                    nic.transfer_as(JobId(1), 100_000).await;
                }));
            }
            // The light tenant arrives after the hog's backlog is queued
            // (the 1 ms timer fires only once the spawned hogs have all
            // taken their queue slots).
            clock::sleep(Duration::from_millis(1)).await;
            let light = {
                let nic = nic.clone();
                crate::rt::spawn(async move {
                    nic.transfer_as(JobId(2), 100_000).await;
                    now()
                })
            };
            let light_done = light.await - t0;
            for h in hogs {
                h.await;
            }
            (light_done, now() - t0)
        })
    }

    #[test]
    fn drr_isolates_light_tenant_from_hog() {
        // 100 KB at 1 MB/s = 0.1 s service time per transfer; 50 hog
        // transfers = 5 s of backlog. Under FIFO the light tenant waits
        // behind all of it; under DRR it is served within ~2 rotations
        // (its 100 KB head needs two 64 KiB quanta).
        let (fifo_light, fifo_total) = hog_scenario(false, 50);
        let (drr_light, drr_total) = hog_scenario(true, 50);
        assert!(
            fifo_light >= Duration::from_secs(5),
            "FIFO must HOL-block the light tenant: {fifo_light:?}"
        );
        assert!(
            drr_light <= Duration::from_millis(500),
            "DRR must serve the light tenant within ~2 rotations: {drr_light:?}"
        );
        // Work conservation: total service time is unchanged.
        assert_eq!(fifo_total, drr_total);
    }

    #[test]
    fn single_job_drr_is_fifo_identical() {
        // The JobId(0)-solo pin: with one job, the DRR scheduler must
        // produce exactly the classic FIFO bandwidth-server timing —
        // each transfer starts when the previous one releases, in
        // arrival order, independent of the quantum. The expectation is
        // built analytically (cumulative service times: every arrival
        // lands while transfer 0 still holds the NIC), so a regression
        // in the DRR path's solo behavior fails against a fixed vector,
        // not against itself.
        const SIZES: [u64; 6] = [10_000, 250_000, 7, 64 * 1024, 1_000_000, 3];
        let run = |fair: bool| {
            crate::rt::run_virtual(async move {
                let nic = Nic::with_queueing(1e6, fair, DEFAULT_NIC_QUANTUM);
                let t0 = now();
                let mut ends = Vec::new();
                let mut handles = Vec::new();
                for (i, bytes) in SIZES.into_iter().enumerate() {
                    let nic = nic.clone();
                    handles.push(crate::rt::spawn(async move {
                        // Staggered arrivals, all within transfer 0's
                        // 10 ms service time.
                        clock::sleep(Duration::from_millis(i as u64)).await;
                        nic.transfer_as(JobId(0), bytes).await;
                        now()
                    }));
                }
                for h in handles {
                    ends.push(h.await - t0);
                }
                ends
            })
        };
        let expected: Vec<Duration> = {
            let nic = Nic::new(1e6);
            let mut done = Duration::ZERO;
            SIZES
                .iter()
                .map(|&b| {
                    done += nic.service_time(b);
                    done
                })
                .collect()
        };
        assert_eq!(run(true), expected, "DRR solo must be exact FIFO");
        assert_eq!(run(false), expected, "FIFO discipline sanity");
    }

    /// One hog (job 1) floods the NIC with quantum-sized transfers; one
    /// light tenant (job 2, DRR weight `w`) queues a single 4-quantum
    /// transfer 1 ms in. Returns (light completion, total makespan).
    fn weighted_hog_scenario(w: u64) -> (Duration, Duration) {
        crate::rt::run_virtual(async move {
            let nic = Nic::with_queueing(1e6, true, DEFAULT_NIC_QUANTUM);
            nic.set_job_weight(JobId(2), w);
            let t0 = now();
            let mut hogs = Vec::new();
            for _ in 0..8 {
                let nic = nic.clone();
                hogs.push(crate::rt::spawn(async move {
                    nic.transfer_as(JobId(1), DEFAULT_NIC_QUANTUM).await;
                }));
            }
            clock::sleep(Duration::from_millis(1)).await;
            let light = {
                let nic = nic.clone();
                crate::rt::spawn(async move {
                    nic.transfer_as(JobId(2), 4 * DEFAULT_NIC_QUANTUM).await;
                    now()
                })
            };
            let light_done = light.await - t0;
            for h in hogs {
                h.await;
            }
            (light_done, now() - t0)
        })
    }

    #[test]
    fn weighted_drr_quanta_pin_the_class_service_ratio() {
        // The light tenant's 4-quantum head needs ceil(4/w) queue visits
        // to accumulate credit — one hog transfer serves per visit, so
        // its completion is exactly (ceil(4/w) + 1) hog slots plus its
        // own service time. Weights 1/2/4 pin the full weighted ratio,
        // and the makespan is identical across weights (weighting moves
        // service order, never total work).
        let nic = Nic::new(1e6);
        let slot = nic.service_time(DEFAULT_NIC_QUANTUM);
        let own = nic.service_time(4 * DEFAULT_NIC_QUANTUM);
        let mut totals = Vec::new();
        for (w, visits) in [(1u64, 4u32), (2, 2), (4, 1)] {
            let (light, total) = weighted_hog_scenario(w);
            assert_eq!(
                light,
                slot * (visits + 1) + own,
                "weight {w} must serve the light head after {visits} visits"
            );
            totals.push(total);
        }
        assert!(
            totals.iter().all(|t| *t == totals[0]),
            "weighting must stay work-conserving: {totals:?}"
        );
    }

    #[test]
    fn solo_job_service_is_weight_independent() {
        // The sole-queue path zeroes the deficit, so a configured weight
        // must not move a lone job's timing by a nanosecond — the
        // single-class inertness pin.
        let run = |weight: Option<u64>| {
            crate::rt::run_virtual(async move {
                let nic = Nic::with_queueing(1e6, true, DEFAULT_NIC_QUANTUM);
                if let Some(w) = weight {
                    nic.set_job_weight(JobId(0), w);
                }
                let t0 = now();
                let mut handles = Vec::new();
                for (i, bytes) in [200_000u64, 50_000, 500_000].into_iter().enumerate() {
                    let nic = nic.clone();
                    handles.push(crate::rt::spawn(async move {
                        clock::sleep(Duration::from_millis(i as u64)).await;
                        nic.transfer_as(JobId(0), bytes).await;
                        now()
                    }));
                }
                let mut ends = Vec::new();
                for h in handles {
                    ends.push(h.await - t0);
                }
                ends
            })
        };
        assert_eq!(run(None), run(Some(9)));
    }

    #[test]
    fn drr_replays_deterministically() {
        let (a_light, a_total) = hog_scenario(true, 20);
        let (b_light, b_total) = hog_scenario(true, 20);
        assert_eq!(a_light, b_light);
        assert_eq!(a_total, b_total);
    }

    #[test]
    fn cancelled_waiter_does_not_wedge_the_nic() {
        crate::rt::run_virtual(async {
            let nic = Nic::new(1000.0);
            // Holder occupies the NIC for 1 s.
            let holder = {
                let nic = nic.clone();
                crate::rt::spawn(async move { nic.transfer_as(JobId(1), 1000).await })
            };
            clock::sleep(Duration::from_millis(1)).await;
            // A queued waiter cancelled by a timeout mid-queue.
            let cancelled = {
                let nic = nic.clone();
                crate::rt::spawn(async move {
                    let _ = crate::rt::timeout(Duration::from_millis(100), async {
                        nic.transfer_as(JobId(2), 1000).await;
                    })
                    .await;
                })
            };
            cancelled.await;
            holder.await;
            // The NIC must still serve new transfers.
            let t0 = now();
            nic.transfer_as(JobId(3), 500).await;
            assert_eq!(now() - t0, Duration::from_millis(500));
        });
    }
}
