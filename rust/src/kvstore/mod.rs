//! Sharded key-value store with pub/sub and atomic counters — the Redis
//! cluster of the paper's deployment (§V: ten c5.18xlarge shards), plus the
//! network cost model that gives every operation a virtual-time price.
//!
//! Multi-tenant: [`KvStore`] is the shared cluster (shard NICs, broker,
//! config); each job operates through its own [`JobArena`] handle, which
//! scopes object/counter storage, channel namespaces, latency-tail
//! streams, and metrics to that job while contending for the shared NICs.

pub mod netmodel;
pub mod pubsub;
pub mod spill;
pub mod store;

pub use netmodel::{Nic, TailLatency, DEFAULT_NIC_QUANTUM};
pub use pubsub::{Message, PubSub, Subscription};
pub use spill::{SpillSettlement, SpillTier};
pub use store::{ArenaForensics, JobArena, KvStore};
