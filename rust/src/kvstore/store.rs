//! The sharded KV store: put/get of data objects, atomic counters
//! (fan-in dependency counters, paper §IV-C), and the pub/sub front end.
//!
//! ## Multi-tenant layout: one cluster, per-job arenas
//!
//! [`KvStore`] is the **shared cluster**: the shard NICs (network
//! endpoints), the pub/sub broker, and the network/fault configuration.
//! Many concurrent jobs run over one cluster; everything a single job
//! stores lives in its [`JobArena`] — the per-job handle every executor
//! holds. The arena owns the job's dense slot storage, its named-key side
//! maps, its seeded latency-tail stream, and the job's metrics hub, so:
//!
//! * two jobs can use the same [`ObjectKey`] (same `TaskId`) without
//!   colliding — job scope is carried by the arena handle, and the packed
//!   key stays a `Copy` `u64` (the hot path allocates nothing);
//! * shard routing mixes job and key (`mix64(key ^ job-salt)`), spreading
//!   concurrent jobs across shard NICs while keeping `JobId(0)` routing
//!   bit-identical to the single-job engine;
//! * NIC queueing is **shared**: bursts from co-resident jobs contend for
//!   the same endpoints, which is exactly the multi-tenant contention the
//!   service scenarios measure.
//!
//! ## Hot-path memory layout (per arena)
//!
//! Keys are packed `u64`s ([`ObjectKey`]) and each arena is backed by
//! **dense per-job slot storage**: task outputs live in a
//! `Vec<Mutex<Option<DataObj>>>` and fan-in counters in a
//! `Vec<AtomicU64>`, both indexed directly by `TaskId` and sized once at
//! job start (arena creation pre-sizes for the DAG). `get`/`put`/
//! `contains` are slot lookups and `incr` is a single `fetch_add` — no
//! `String` allocation, no byte hashing, and no map mutex anywhere on the
//! task-output/counter path.
//!
//! Keys outside the task range ([`ObjectKey::named`]) go to a small
//! hash-keyed side map, and the forensic/introspection API
//! ([`JobArena::object_keys`] / [`JobArena::counter_entries`]) renders key
//! strings lazily via `Display`, byte-identical to the strings the
//! pre-packing implementation stored.
//!
//! ## Arena lifecycle and reclamation (resource governance)
//!
//! The cluster keeps an **arena registry**: every arena registers at
//! creation and reports its resident bytes (dense slots + named maps)
//! into a cluster-wide ledger, updated delta-wise on every store. At job
//! end the service calls [`KvStore::retire`], which marks the job's
//! arenas finished (stamping a retirement sequence number) and tears
//! down the job's pub/sub namespace. Retired arenas may keep their
//! intermediates resident — a tenant can still fetch results — until
//! [`KvStore::enforce_kv_budget`] evicts **oldest-finished-first** to
//! keep the bytes retained by finished jobs under the service's byte
//! budget (deterministically: the retirement sequence is the only
//! eviction order). Running jobs are never evicted and their live bytes
//! never count against the budget. A budget of zero retains nothing:
//! every retired arena is reclaimed immediately, which is the
//! post-retirement-emptiness invariant the multi-job oracle pins.

use crate::compute::DataObj;
use crate::core::{
    clock, mix64, EngineError, EngineResult, FaultConfig, JobId, NetConfig, ObjectKey, SpillConfig,
    TaskId,
};
use crate::kvstore::netmodel::{Nic, TailLatency};
use crate::kvstore::pubsub::{Message, PubSub, Subscription};
use crate::kvstore::spill::SpillTier;
use crate::metrics::{KvOpKind, MetricsHub};
use std::collections::{HashMap, HashSet};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, RwLock, Weak};
use std::time::Duration;

/// Per-arena tail-stream salt base: `JobId(0)`'s stream is bit-identical
/// to the single-store stream of the pre-arena engine.
const TAIL_SALT: u64 = 0x6b76;

/// One shard: a network endpoint. All data lives in the dense slot arrays
/// of the job arenas; the shard contributes only its NIC
/// (latency/bandwidth queueing), which co-resident jobs contend for.
struct Shard {
    nic: Arc<Nic>,
}

/// Dense per-job slot storage, indexed by `TaskId`. Sized once at job
/// start; growth after that is a cold path taken only by tests that
/// store ad-hoc keys.
#[derive(Default)]
struct TaskSlots {
    objects: Vec<Mutex<Option<DataObj>>>,
    counters: Vec<AtomicU64>,
}

/// One registered arena in the cluster's registry.
struct RegEntry {
    /// Unique per registration (two arenas of one job id stay distinct).
    uid: u64,
    job: u64,
    arena: Weak<JobArena>,
    /// `Some(seq)` once the job retired; `seq` orders eviction
    /// (oldest-finished-first).
    retired_seq: Option<u64>,
}

/// The cluster-side arena registry: who is attached, who has retired,
/// and in what order retirements happened.
#[derive(Default)]
struct ArenaRegistry {
    entries: Vec<RegEntry>,
    next_uid: u64,
    next_retire_seq: u64,
}

/// Snapshot of one arena's forensic state, captured **before**
/// retirement so the differential oracle can check store-once /
/// counter invariants even after the arena's storage has been
/// reclaimed by the byte-budget eviction policy.
#[derive(Clone, Debug, PartialEq)]
pub struct ArenaForensics {
    /// Rendered object keys, sorted (see [`JobArena::object_keys`]).
    pub object_keys: Vec<String>,
    /// Rendered counters and final values, sorted
    /// (see [`JobArena::counter_entries`]).
    pub counter_entries: Vec<(String, u64)>,
    /// Resident payload bytes at capture time.
    pub resident_bytes: u64,
}

/// The shared KV cluster. Cloneable by `Arc`; jobs attach via
/// [`KvStore::arena`] / [`KvStore::arena_with_metrics`].
pub struct KvStore {
    shards: Vec<Shard>,
    pubsub: PubSub,
    cfg: NetConfig,
    /// Fault profile; each arena derives its own seeded tail stream from
    /// it, so one job's op mix never perturbs another job's draws.
    faults: FaultConfig,
    /// Default metrics hub for arenas created without an explicit one
    /// (single-job runs, tests).
    metrics: Arc<MetricsHub>,
    /// "Ideal storage" mode (Fig. 10 yellow bars): data still flows so
    /// real-compute jobs stay correct, but every transfer is free.
    ideal: bool,
    /// Arena registry: every attached job, its retirement order, and the
    /// weak handles the eviction policy reclaims through.
    registry: Mutex<ArenaRegistry>,
    /// Cluster-wide resident-byte ledger (sum of every arena's resident
    /// payload bytes), updated delta-wise on each store/evict/drop.
    resident_total: AtomicU64,
    /// The cold spill tier below the KV cluster. When enabled, budget
    /// eviction demotes retired arenas' payloads here instead of
    /// destroying them; disabled (default) it is inert and eviction is
    /// destruction, bit-identical to the pre-spill engine.
    spill: SpillTier,
}

impl KvStore {
    pub fn new(cfg: NetConfig, metrics: Arc<MetricsHub>) -> Arc<Self> {
        Self::with_ideal(cfg, metrics, false)
    }

    pub fn with_ideal(cfg: NetConfig, metrics: Arc<MetricsHub>, ideal: bool) -> Arc<Self> {
        Self::with_faults(cfg, FaultConfig::default(), metrics, ideal)
    }

    /// Constructor with fault profile; the spill tier stays at its inert
    /// default (eviction is destruction).
    pub fn with_faults(
        cfg: NetConfig,
        faults: FaultConfig,
        metrics: Arc<MetricsHub>,
        ideal: bool,
    ) -> Arc<Self> {
        Self::with_spill(cfg, faults, metrics, ideal, SpillConfig::default())
    }

    /// Full constructor: network config, fault-injection profile, ideal
    /// mode, spill tier. Fault draws are seeded, so identical runs sample
    /// identical latency tails (the spill tier derives its own stream).
    pub fn with_spill(
        cfg: NetConfig,
        faults: FaultConfig,
        metrics: Arc<MetricsHub>,
        ideal: bool,
        spill: SpillConfig,
    ) -> Arc<Self> {
        assert!(cfg.kv_shards > 0);
        // Shard-per-VM: each shard gets its own NIC. Shared-VM mode (the
        // pre-optimization configuration of Fig. 12): one NIC serves all
        // shards, so bursts contend.
        let mk_nic = || {
            Nic::with_queueing(
                cfg.kv_bandwidth_bps,
                cfg.nic_fair_queueing,
                cfg.nic_drr_quantum_bytes,
            )
        };
        let shared: Option<Arc<Nic>> = if cfg.kv_shared_vm { Some(mk_nic()) } else { None };
        let shards = (0..cfg.kv_shards)
            .map(|_| Shard {
                nic: shared.clone().unwrap_or_else(mk_nic),
            })
            .collect();
        let spill = SpillTier::new(spill, &faults);
        Arc::new(KvStore {
            shards,
            pubsub: PubSub::new(),
            cfg,
            faults,
            metrics,
            ideal,
            registry: Mutex::new(ArenaRegistry::default()),
            resident_total: AtomicU64::new(0),
            spill,
        })
    }

    /// The cluster's cold spill tier (billing settlement, reports).
    pub fn spill(&self) -> &SpillTier {
        &self.spill
    }

    /// Attaches a job to the cluster: creates its arena with slot storage
    /// pre-sized for a DAG of `n_tasks`, recording into the store's
    /// default metrics hub (single-job runs, tests).
    pub fn arena(self: &Arc<Self>, job: JobId, n_tasks: usize) -> Arc<JobArena> {
        self.arena_with_metrics(job, n_tasks, self.metrics.clone())
    }

    /// Attaches a job with its own metrics hub — the multi-tenant entry
    /// point: each concurrent job records its KV traffic into its own
    /// per-job hub while sharing the cluster's NICs and broker.
    pub fn arena_with_metrics(
        self: &Arc<Self>,
        job: JobId,
        n_tasks: usize,
        metrics: Arc<MetricsHub>,
    ) -> Arc<JobArena> {
        // Registration allocates the cluster-wide `uid`, which orders
        // spill-set settlement and forensic teardown; under sharded
        // simulation the allocation must land in virtual-time order, so
        // the whole (synchronous) registration is one gate sequence
        // point. No-op in serial runs.
        let _gate = crate::rt::sharded::gate();
        let uid = {
            let mut reg = self.registry.lock().unwrap();
            let uid = reg.next_uid;
            reg.next_uid += 1;
            uid
        };
        let arena = JobArena {
            store: Arc::clone(self),
            job,
            uid,
            // Multiplicative salt keeps JobId(0) routing bit-identical to
            // the pre-arena store (salt 0 => mix64(key) exactly).
            shard_salt: job.0.wrapping_mul(0x9E37_79B9_7F4A_7C15),
            slots: RwLock::new(TaskSlots::default()),
            named_objects: Mutex::new(HashMap::new()),
            named_counters: Mutex::new(HashMap::new()),
            resident: AtomicU64::new(0),
            net_bytes: AtomicU64::new(0),
            metrics,
            tail: TailLatency::from_faults(
                &self.faults,
                TAIL_SALT ^ job.0.wrapping_mul(0xA24B_AED4_963E_E407),
            ),
            edge_dedup: Mutex::new(None),
        };
        arena.ensure_task_capacity(n_tasks);
        let arena = Arc::new(arena);
        self.registry.lock().unwrap().entries.push(RegEntry {
            uid,
            job: job.0,
            arena: Arc::downgrade(&arena),
            retired_seq: None,
        });
        arena
    }

    /// Tears down `job`'s pub/sub namespace (job complete). Keeps the
    /// broker bounded when many jobs stream through one shared store.
    pub fn remove_job_channels(&self, job: JobId) {
        self.pubsub.remove_job(job);
    }

    /// Retires `job`: stamps its arenas with the next retirement sequence
    /// number (the deterministic eviction order) and tears down its
    /// pub/sub namespace. The arenas' data stays resident — still
    /// fetchable post-job — until [`KvStore::enforce_kv_budget`] evicts
    /// it under byte-budget pressure. Idempotent.
    pub fn retire(&self, job: JobId) {
        self.set_job_nic_weight(job, 1); // weight entries die with the job
        {
            let mut reg = self.registry.lock().unwrap();
            for i in 0..reg.entries.len() {
                if reg.entries[i].job == job.0 && reg.entries[i].retired_seq.is_none() {
                    let seq = reg.next_retire_seq;
                    reg.next_retire_seq += 1;
                    reg.entries[i].retired_seq = Some(seq);
                }
            }
        }
        self.pubsub.remove_job(job);
    }

    /// Evicts retired arenas **oldest-finished-first** until the bytes
    /// retained by *finished* jobs are at most `budget`; a budget of zero
    /// additionally drains every retired arena (retain nothing). The
    /// budget meters only retired arenas — running jobs' live
    /// intermediates are never evicted and never count against it, so a
    /// heavy in-flight job cannot force a finished tenant's retained
    /// results out. Returns the evicted jobs in eviction order. Free in
    /// virtual time (a DEL of finished intermediates is bookkeeping, not
    /// data-path traffic).
    pub fn enforce_kv_budget(&self, budget: u64) -> Vec<JobId> {
        let mut evicted = Vec::new();
        loop {
            let victim = {
                let mut reg = self.registry.lock().unwrap();
                let mut retired_resident = 0u64;
                let mut oldest: Option<usize> = None;
                let mut oldest_seq = u64::MAX;
                for (i, e) in reg.entries.iter().enumerate() {
                    let Some(seq) = e.retired_seq else { continue };
                    // The upgraded temp Arc is safe to drop under the
                    // lock: `upgrade` succeeding means another strong
                    // ref exists, so this can never run the arena's
                    // Drop (which re-locks the registry).
                    if let Some(arena) = e.arena.upgrade() {
                        retired_resident =
                            retired_resident.saturating_add(arena.resident_bytes());
                    }
                    if seq < oldest_seq {
                        oldest_seq = seq;
                        oldest = Some(i);
                    }
                }
                match oldest {
                    Some(i) if retired_resident > budget || budget == 0 => {
                        Some(reg.entries.remove(i))
                    }
                    _ => None,
                }
            };
            let Some(entry) = victim else {
                return evicted; // retained bytes under budget, or only running jobs left
            };
            // Reclaim outside the registry lock: dropping the upgraded
            // Arc here may run the arena's Drop, which re-locks the
            // registry (finding its entry already gone). With the spill
            // tier enabled, eviction is demotion instead of destruction:
            // the arena's payload parks in the cold tier, still
            // fetchable (at cold prices) through the same handle.
            if let Some(arena) = entry.arena.upgrade() {
                if self.spill.enabled() {
                    arena.demote_to_spill();
                } else {
                    arena.reclaim();
                }
                evicted.push(JobId(entry.job));
            }
        }
    }

    /// Total resident payload bytes across every attached arena (the
    /// byte-budget ledger).
    pub fn resident_kv_bytes(&self) -> u64 {
        self.resident_total.load(Ordering::Relaxed)
    }

    /// Number of arenas currently in the registry (running + retired but
    /// not yet evicted). Zero after every job has retired under a zero
    /// byte budget — the substrate-emptiness invariant.
    pub fn registered_arena_count(&self) -> usize {
        self.registry.lock().unwrap().entries.len()
    }

    /// Number of live pub/sub job namespaces on the broker.
    pub fn pubsub_namespace_count(&self) -> usize {
        self.pubsub.namespace_count()
    }

    /// Sets `job`'s DRR scheduling weight on every shard NIC (weight 1 —
    /// the default — clears the entry; see [`Nic::set_job_weight`]). The
    /// job service plumbs `NetConfig::nic_drr_class_weights` through
    /// here at admission; [`KvStore::retire`] clears it.
    pub fn set_job_nic_weight(&self, job: JobId, weight: u64) {
        for s in &self.shards {
            s.nic.set_job_weight(job, weight);
        }
    }

    /// Number of shards (tests / reports).
    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }
}

/// One job's handle onto the shared cluster: dense slot storage scoped to
/// the job, job-namespaced pub/sub, a per-job latency-tail stream, and
/// the job's metrics hub. Every executor of the job holds this; the
/// packed [`ObjectKey`] stays job-agnostic, so the PR-3 hot path is
/// unchanged — job scope is the handle, not the key.
pub struct JobArena {
    store: Arc<KvStore>,
    job: JobId,
    /// Registry identity (unique per attach, even for a reused `JobId`).
    uid: u64,
    /// Mixed into shard routing so concurrent jobs spread over the NICs.
    shard_salt: u64,
    /// Dense task-output / fan-in-counter slots (the hot path).
    slots: RwLock<TaskSlots>,
    /// Side maps for the namespaced non-task key range, keyed by the
    /// packed key word.
    named_objects: Mutex<HashMap<u64, DataObj>>,
    named_counters: Mutex<HashMap<u64, u64>>,
    /// Resident payload bytes of this arena (dense slots + named map),
    /// mirrored delta-wise into the cluster ledger.
    resident: AtomicU64,
    /// Per-job traffic ledger: payload bytes this job actually moved over
    /// shard NICs (put + get transfers). Control round trips — incr,
    /// exists, publish — carry no payload and are not counted, and an
    /// ideal store moves nothing. Locality-enhanced scheduling is judged
    /// against exactly this number.
    net_bytes: AtomicU64,
    metrics: Arc<MetricsHub>,
    /// Seeded heavy-tail latency injection (pass-through when benign),
    /// streamed per job for cross-job determinism.
    tail: TailLatency,
    /// Committed fan-in edges (packed `child << 32 | parent`), allocated
    /// only when crash recovery arms edge dedup. `None` (the default)
    /// keeps the benign hot path a bare `fetch_add` with no set lookup —
    /// `incr_edge` then behaves exactly like `incr`.
    edge_dedup: Mutex<Option<HashSet<u64>>>,
}

fn pack_edge(child: TaskId, parent: TaskId) -> u64 {
    ((child.0 as u64) << 32) | parent.0 as u64
}

impl JobArena {
    /// The job this arena belongs to.
    pub fn job(&self) -> JobId {
        self.job
    }

    /// The shared cluster this arena routes through.
    pub fn store(&self) -> &Arc<KvStore> {
        &self.store
    }

    /// Pre-sizes the dense slot storage for a DAG of `n` tasks. Arena
    /// creation does this once (the DAG size is always known up front),
    /// so every subsequent task-key operation is a pure index lookup with
    /// no growth check taken.
    pub fn ensure_task_capacity(&self, n: usize) {
        {
            let r = self.slots.read().unwrap();
            if r.objects.len() >= n && r.counters.len() >= n {
                return;
            }
        }
        let mut w = self.slots.write().unwrap();
        while w.objects.len() < n {
            w.objects.push(Mutex::new(None));
        }
        while w.counters.len() < n {
            w.counters.push(AtomicU64::new(0));
        }
    }

    /// Shard routing: one integer mix of the packed key word and the
    /// job salt — no byte hashing, no allocation.
    fn shard_of(&self, key: ObjectKey) -> &Shard {
        let h = mix64(key.raw() ^ self.shard_salt);
        &self.store.shards[(h % self.store.shards.len() as u64) as usize]
    }

    fn latency(&self) -> Duration {
        Duration::from_secs_f64(self.store.cfg.kv_latency_us * 1e-6)
    }

    /// Mirrors a store/replace/evict into the arena's resident-byte
    /// counter and the cluster ledger (delta accounting, so replacing an
    /// object charges only the size difference).
    fn account(&self, added: u64, removed: u64) {
        if added > removed {
            let d = added - removed;
            self.resident.fetch_add(d, Ordering::Relaxed);
            self.store.resident_total.fetch_add(d, Ordering::Relaxed);
        } else if removed > added {
            let d = removed - added;
            self.resident.fetch_sub(d, Ordering::Relaxed);
            self.store.resident_total.fetch_sub(d, Ordering::Relaxed);
        }
    }

    /// Writes `obj` into the slot / side map for `key` (no modeled cost).
    fn store_obj(&self, key: ObjectKey, obj: DataObj) {
        let added = obj.bytes;
        let removed = match key.object_slot() {
            Some(i) => {
                // `take()` keeps the value re-armable across the (at most
                // one) growth retry without moving out of a loop.
                let mut obj = Some(obj);
                loop {
                    {
                        let slots = self.slots.read().unwrap();
                        if let Some(slot) = slots.objects.get(i) {
                            let old = std::mem::replace(&mut *slot.lock().unwrap(), obj.take());
                            break old.map_or(0, |o| o.bytes);
                        }
                    }
                    self.ensure_task_capacity(i + 1);
                }
            }
            None => self
                .named_objects
                .lock()
                .unwrap()
                .insert(key.raw(), obj)
                .map_or(0, |o| o.bytes),
        };
        self.account(added, removed);
    }

    /// Drops this arena's slot storage and side maps, zeroing its entry
    /// in the cluster's resident-byte ledger. Called by the eviction
    /// policy on retired arenas; subsequent `get`s see missing objects.
    fn reclaim(&self) -> u64 {
        {
            let mut w = self.slots.write().unwrap();
            *w = TaskSlots::default();
        }
        self.named_objects.lock().unwrap().clear();
        self.named_counters.lock().unwrap().clear();
        let freed = self.resident.swap(0, Ordering::Relaxed);
        self.store.resident_total.fetch_sub(freed, Ordering::Relaxed);
        freed
    }

    /// Spill-enabled eviction: moves every payload object out of the KV
    /// cluster into the cold tier, zeroing the arena's resident-byte
    /// ledger entry exactly like [`JobArena::reclaim`]. Fan-in counters
    /// are bookkeeping for a finished DAG and are simply dropped. The
    /// demotion transfer counts as real network traffic (KV shard →
    /// cold store), feeding the per-job and fleet `net_bytes_moved`
    /// ledgers; like the eviction DEL it is free in *virtual time* —
    /// the cost model charges the cold **read** path instead. Returns
    /// the demoted bytes.
    fn demote_to_spill(&self) -> u64 {
        let slots = {
            let mut w = self.slots.write().unwrap();
            std::mem::take(&mut *w)
        };
        let mut payloads: Vec<(u64, DataObj)> = slots
            .objects
            .into_iter()
            .enumerate()
            .filter_map(|(i, slot)| {
                let obj = slot.into_inner().unwrap()?;
                Some((ObjectKey::output(crate::core::TaskId(i as u32)).raw(), obj))
            })
            .collect();
        payloads.extend(self.named_objects.lock().unwrap().drain());
        self.named_counters.lock().unwrap().clear();
        let freed = self.resident.swap(0, Ordering::Relaxed);
        self.store.resident_total.fetch_sub(freed, Ordering::Relaxed);
        let moved = self
            .store
            .spill
            .demote(self.uid, self.job.0, payloads, clock::now());
        if moved > 0 {
            self.net_bytes.fetch_add(moved, Ordering::Relaxed);
            self.metrics.record_net_bytes(moved);
            self.metrics.record_spill_demotion(moved);
        }
        moved
    }

    /// Reads the object for `key` (no modeled cost).
    fn load_obj(&self, key: ObjectKey) -> Option<DataObj> {
        match key.object_slot() {
            Some(i) => {
                let slots = self.slots.read().unwrap();
                slots.objects.get(i)?.lock().unwrap().clone()
            }
            None => self.named_objects.lock().unwrap().get(&key.raw()).cloned(),
        }
    }

    /// Stores `obj` under `key`, charging latency + bandwidth.
    pub async fn put(&self, key: ObjectKey, obj: DataObj, client_bps: f64) {
        let t0 = clock::now();
        let bytes = obj.bytes;
        let shard = self.shard_of(key);
        if !self.store.ideal {
            clock::sleep(self.tail.sample(self.latency())).await;
            shard.nic.transfer_capped_as(self.job, bytes, client_bps).await;
            self.net_bytes.fetch_add(bytes, Ordering::Relaxed);
            self.metrics.record_net_bytes(bytes);
        }
        self.store_obj(key, obj);
        self.metrics
            .record_kv_op(KvOpKind::Write, bytes, clock::now() - t0);
    }

    /// Retrieves the object under `key`, charging latency + bandwidth.
    /// When the KV cluster no longer holds the object (this arena was
    /// budget-evicted after retirement), the read falls through to the
    /// cold spill tier and pays the cold penalty instead of failing —
    /// `MissingObject` remains only for keys that were never stored (or
    /// were destroyed with the spill tier disabled).
    pub async fn get(&self, key: ObjectKey, client_bps: f64) -> EngineResult<DataObj> {
        let t0 = clock::now();
        let Some(obj) = self.load_obj(key) else {
            return self.get_cold(key, t0).await;
        };
        if !self.store.ideal {
            clock::sleep(self.tail.sample(self.latency())).await;
            self.shard_of(key)
                .nic
                .transfer_capped_as(self.job, obj.bytes, client_bps)
                .await;
            self.net_bytes.fetch_add(obj.bytes, Ordering::Relaxed);
            self.metrics.record_net_bytes(obj.bytes);
        }
        self.metrics
            .record_kv_op(KvOpKind::Read, obj.bytes, clock::now() - t0);
        Ok(obj)
    }

    /// The cold half of [`JobArena::get`]: serves a demoted object from
    /// the spill tier, sleeping the tier's seeded latency + streaming
    /// penalty. The cold store is its own endpoint — shard NICs are not
    /// held, so a burst of cold fetches never head-of-line-blocks live
    /// jobs' KV traffic. Under `SpillConfig::promote_after_reads` the
    /// Nth cold read promotes the object: the tier hands it back for the
    /// last time and the arena re-inserts it warm, so further reads are
    /// served from the KV cluster at warm cost.
    async fn get_cold(&self, key: ObjectKey, t0: clock::SimInstant) -> EngineResult<DataObj> {
        let Some((obj, promoted)) =
            self.store.spill.read_promoting(self.uid, key.raw(), clock::now())
        else {
            return Err(EngineError::MissingObject {
                key: key.to_string(),
            });
        };
        if !self.store.ideal {
            clock::sleep(self.store.spill.read_penalty(obj.bytes)).await;
            self.net_bytes.fetch_add(obj.bytes, Ordering::Relaxed);
            self.metrics.record_net_bytes(obj.bytes);
        }
        self.metrics.record_spill_read(obj.bytes);
        if promoted {
            // Promotion is the cold transfer this read already paid for,
            // landing in the warm tier instead of evaporating: no extra
            // modeled cost, same accounting as any other store.
            self.store_obj(key, obj.clone());
            self.metrics.record_spill_promotion();
        }
        self.metrics
            .record_kv_op(KvOpKind::Read, obj.bytes, clock::now() - t0);
        Ok(obj)
    }

    /// Checks existence without transferring the value. An EXISTS is a
    /// real round trip on a real Redis, so it is charged request + reply
    /// latency like `incr` — unless the `NetConfig::charge_exists` escape
    /// hatch is off (or the store is ideal).
    pub async fn contains(&self, key: ObjectKey) -> bool {
        let t0 = clock::now();
        if !self.store.ideal && self.store.cfg.charge_exists {
            clock::sleep(self.tail.sample(self.latency() * 2)).await; // request + reply
        }
        let hit = self.peek_contains(key);
        self.metrics
            .record_kv_op(KvOpKind::Exists, 0, clock::now() - t0);
        hit
    }

    /// Free, synchronous existence probe for forensic/post-mortem checks
    /// (the differential oracle, tests) — never touches virtual time and
    /// records no metrics.
    pub fn peek_contains(&self, key: ObjectKey) -> bool {
        match key.object_slot() {
            Some(i) => {
                let slots = self.slots.read().unwrap();
                slots
                    .objects
                    .get(i)
                    .is_some_and(|slot| slot.lock().unwrap().is_some())
            }
            None => self.named_objects.lock().unwrap().contains_key(&key.raw()),
        }
    }

    /// Free, synchronous availability probe spanning both the resident
    /// KV tier and the cold spill tier. The recovery watchdog's lineage
    /// walk uses this to decide whether an intermediate must be
    /// recomputed: an object demoted to the spill tier is still
    /// recoverable by a plain [`JobArena::get`], so it does not count as
    /// lost.
    pub fn peek_available(&self, key: ObjectKey) -> bool {
        self.peek_contains(key) || self.store.spill.peek(self.uid, key.raw())
    }

    /// Atomically increments the counter at `key` and returns the new
    /// value (Redis INCR — the fan-in dependency counter of paper §IV-C).
    /// Small fixed-size message: round-trip latency only. On the
    /// task-counter path this is one `fetch_add` on a dense slot — no
    /// mutex, no allocation.
    pub async fn incr(&self, key: ObjectKey) -> u64 {
        let t0 = clock::now();
        if !self.store.ideal {
            clock::sleep(self.tail.sample(self.latency() * 2)).await; // request + reply
        }
        let v = self.incr_value(key);
        self.metrics
            .record_kv_op(KvOpKind::Incr, 0, clock::now() - t0);
        v
    }

    /// The synchronous counter bump behind [`JobArena::incr`] /
    /// [`JobArena::incr_edge`] — no virtual time, no metrics.
    fn incr_value(&self, key: ObjectKey) -> u64 {
        match key.counter_slot() {
            Some(i) => loop {
                {
                    let slots = self.slots.read().unwrap();
                    if let Some(c) = slots.counters.get(i) {
                        break c.fetch_add(1, Ordering::Relaxed) + 1;
                    }
                }
                self.ensure_task_capacity(i + 1);
            },
            None => {
                let mut m = self.named_counters.lock().unwrap();
                let e = m.entry(key.raw()).or_insert(0);
                *e += 1;
                *e
            }
        }
    }

    /// Arms fan-in **edge dedup** for this arena (crash recovery): each
    /// `parent -> child` in-edge commits its INCR at most once, so a
    /// re-executed parent's duplicate delivery can never push a fan-in
    /// counter past the child's in-degree. Off by default — see the
    /// `edge_dedup` field. Idempotent.
    pub fn enable_edge_dedup(&self) {
        let mut d = self.edge_dedup.lock().unwrap();
        if d.is_none() {
            *d = Some(HashSet::new());
        }
    }

    /// Free, synchronous probe: has the in-edge `parent -> child` already
    /// committed its fan-in increment? Always `false` while edge dedup is
    /// disarmed. The recovery watchdog's lineage walk uses this to tell a
    /// delivered edge from one lost with its crashed chain.
    pub fn edge_committed(&self, child: TaskId, parent: TaskId) -> bool {
        self.edge_dedup
            .lock()
            .unwrap()
            .as_ref()
            .is_some_and(|s| s.contains(&pack_edge(child, parent)))
    }

    /// Fan-in increment of `key` (the counter of `child`) attributed to
    /// the in-edge arriving from `parent`. With edge dedup disarmed this
    /// is bit-identical to [`JobArena::incr`]. Armed, a duplicate
    /// delivery of an already-committed edge still pays the round trip
    /// (the retry's INCR really goes to the wire) but does not move the
    /// counter and returns `None` — the caller must treat itself as "not
    /// the last writer" and end its chain. The commit (set insert +
    /// `fetch_add`) is one synchronous section, so a chain dropped
    /// mid-crash either committed its edge or left it fully uncommitted.
    pub async fn incr_edge(&self, key: ObjectKey, child: TaskId, parent: TaskId) -> Option<u64> {
        let t0 = clock::now();
        if !self.store.ideal {
            clock::sleep(self.tail.sample(self.latency() * 2)).await; // request + reply
        }
        {
            let mut d = self.edge_dedup.lock().unwrap();
            if let Some(set) = d.as_mut() {
                if !set.insert(pack_edge(child, parent)) {
                    self.metrics
                        .record_kv_op(KvOpKind::Incr, 0, clock::now() - t0);
                    return None;
                }
            }
        }
        let v = self.incr_value(key);
        self.metrics
            .record_kv_op(KvOpKind::Incr, 0, clock::now() - t0);
        Some(v)
    }

    /// Reads a counter without incrementing (tests / debugging).
    pub fn counter_value(&self, key: ObjectKey) -> u64 {
        match key.counter_slot() {
            Some(i) => {
                let slots = self.slots.read().unwrap();
                slots
                    .counters
                    .get(i)
                    .map_or(0, |c| c.load(Ordering::Relaxed))
            }
            None => *self
                .named_counters
                .lock()
                .unwrap()
                .get(&key.raw())
                .unwrap_or(&0),
        }
    }

    /// Publishes `msg` on this job's `channel` with pub/sub delivery
    /// latency. Channels are namespaced per job (see [`PubSub`]), so
    /// concurrent jobs sharing well-known channel names never
    /// cross-deliver.
    pub async fn publish(&self, channel: &str, msg: Message) -> usize {
        let t0 = clock::now();
        if !self.store.ideal {
            clock::sleep(self.tail.sample(Duration::from_secs_f64(
                self.store.cfg.pubsub_latency_us * 1e-6,
            )))
            .await;
        }
        let n = self.store.pubsub.publish(self.job, channel, msg);
        self.metrics
            .record_kv_op(KvOpKind::Publish, 0, clock::now() - t0);
        n
    }

    /// Subscribes to this job's `channel` (no modeled cost: subscriptions
    /// are set up once at job start, like Dask's cluster-init
    /// connections).
    pub fn subscribe(&self, channel: &str) -> Subscription {
        self.store.pubsub.subscribe(self.job, channel)
    }

    /// Tears down this job's pub/sub namespace (job complete).
    pub fn remove_job_channels(&self) {
        self.store.pubsub.remove_job(self.job);
    }

    /// Number of stored objects (tests / reports).
    pub fn object_count(&self) -> usize {
        let slots = self.slots.read().unwrap();
        let dense = slots
            .objects
            .iter()
            .filter(|slot| slot.lock().unwrap().is_some())
            .count();
        dense + self.named_objects.lock().unwrap().len()
    }

    /// Every stored object key, rendered and sorted (forensic inspection:
    /// the differential oracle checks for orphaned intermediates after a
    /// job completes). Rendering is lazy `Display` of the packed keys —
    /// byte-identical to the strings the pre-packing store held.
    pub fn object_keys(&self) -> Vec<String> {
        let mut keys: Vec<String> = {
            let slots = self.slots.read().unwrap();
            slots
                .objects
                .iter()
                .enumerate()
                .filter(|(_, slot)| slot.lock().unwrap().is_some())
                .map(|(i, _)| ObjectKey::output(crate::core::TaskId(i as u32)).to_string())
                .collect()
        };
        keys.extend(
            self.named_objects
                .lock()
                .unwrap()
                .keys()
                .map(|&raw| ObjectKey::from_raw(raw).to_string()),
        );
        keys.sort();
        keys
    }

    /// Every counter and its final value, sorted by rendered key
    /// (forensic inspection: fan-in counters must end exactly at
    /// in-degree). Zero-valued dense slots are "absent" counters.
    pub fn counter_entries(&self) -> Vec<(String, u64)> {
        let mut entries: Vec<(String, u64)> = {
            let slots = self.slots.read().unwrap();
            slots
                .counters
                .iter()
                .enumerate()
                .filter_map(|(i, c)| {
                    let v = c.load(Ordering::Relaxed);
                    (v > 0).then(|| {
                        (
                            ObjectKey::counter(crate::core::TaskId(i as u32)).to_string(),
                            v,
                        )
                    })
                })
                .collect()
        };
        entries.extend(
            self.named_counters
                .lock()
                .unwrap()
                .iter()
                .map(|(&raw, &v)| (ObjectKey::from_raw(raw).to_string(), v)),
        );
        entries.sort();
        entries
    }

    /// Total stored bytes across all slots.
    pub fn stored_bytes(&self) -> u64 {
        let slots = self.slots.read().unwrap();
        let dense: u64 = slots
            .objects
            .iter()
            .filter_map(|slot| slot.lock().unwrap().as_ref().map(|o| o.bytes))
            .sum();
        dense
            + self
                .named_objects
                .lock()
                .unwrap()
                .values()
                .map(|o| o.bytes)
                .sum::<u64>()
    }

    /// Resident payload bytes per the delta-maintained counter (equals
    /// [`JobArena::stored_bytes`]; O(1), and zero after eviction).
    pub fn resident_bytes(&self) -> u64 {
        self.resident.load(Ordering::Relaxed)
    }

    /// Payload bytes this job moved over shard NICs so far (put + get
    /// transfers; control round trips and ideal-store operations move
    /// nothing). The per-job traffic ledger behind
    /// `JobReport::net_bytes_moved`.
    pub fn net_bytes_moved(&self) -> u64 {
        self.net_bytes.load(Ordering::Relaxed)
    }

    /// Captures this arena's forensic state (rendered keys, counters,
    /// resident bytes) — taken by the job service just before retirement
    /// so post-mortem invariant checks survive budget eviction.
    pub fn forensics(&self) -> ArenaForensics {
        ArenaForensics {
            object_keys: self.object_keys(),
            counter_entries: self.counter_entries(),
            resident_bytes: self.resident_bytes(),
        }
    }
}

impl Drop for JobArena {
    fn drop(&mut self) {
        // The last handle died without an explicit retire/evict (e.g. a
        // single-job forensic run going out of scope): settle the ledger
        // and deregister, so the shared cluster never counts dead bytes.
        // A demoted arena's spill set settles too — at the tier's
        // high-water mark, because Drop may run outside the virtual-time
        // executor where the clock is unavailable. Idempotent against
        // the service's end-of-run `purge_all`.
        let freed = self.resident.swap(0, Ordering::Relaxed);
        self.store.resident_total.fetch_sub(freed, Ordering::Relaxed);
        self.store.spill.purge_at_high_water(self.uid);
        self.store
            .registry
            .lock()
            .unwrap()
            .entries
            .retain(|e| e.uid != self.uid);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::TaskId;

    fn arena() -> Arc<JobArena> {
        KvStore::new(NetConfig::default(), Arc::new(MetricsHub::new())).arena(JobId(0), 0)
    }

    #[test]
    fn put_get_roundtrip() {
        crate::rt::run_virtual(async {
            let kv = arena();
            let key = ObjectKey::output(TaskId(1));
            kv.put(key, DataObj::synthetic(1024), 1e9).await;
            let obj = kv.get(key, 1e9).await.unwrap();
            assert_eq!(obj.bytes, 1024);
            assert_eq!(kv.object_count(), 1);
            assert_eq!(kv.stored_bytes(), 1024);
        });
    }

    #[test]
    fn missing_key_errors() {
        crate::rt::run_virtual(async {
            let kv = arena();
            let err = kv.get(ObjectKey::output(TaskId(9)), 1e9).await.unwrap_err();
            assert!(matches!(err, EngineError::MissingObject { .. }));
        });
    }

    #[test]
    fn incr_concurrent_fan_in_ends_exactly_at_1000() {
        // 1000 concurrent increments of one fan-in counter: every INCR
        // observes a distinct value and the counter ends exactly at 1000
        // — the atomicity the last-writer-continues rule rests on.
        crate::rt::run_virtual(async {
            let kv = arena();
            let key = ObjectKey::counter(TaskId(3));
            let handles: Vec<_> = (0..1000)
                .map(|_| {
                    let kv = kv.clone();
                    crate::rt::spawn(async move { kv.incr(key).await })
                })
                .collect();
            let mut seen = Vec::with_capacity(1000);
            for h in handles {
                seen.push(h.await);
            }
            seen.sort_unstable();
            assert_eq!(seen, (1..=1000).collect::<Vec<u64>>());
            assert_eq!(kv.counter_value(key), 1000);
        });
    }

    #[test]
    fn incr_edge_disarmed_matches_incr_and_armed_dedups() {
        crate::rt::run_virtual(async {
            let kv = arena();
            let child = TaskId(7);
            let key = ObjectKey::counter(child);
            // Disarmed: behaves exactly like incr — duplicates count.
            assert_eq!(kv.incr_edge(key, child, TaskId(1)).await, Some(1));
            assert_eq!(kv.incr_edge(key, child, TaskId(1)).await, Some(2));
            assert!(!kv.edge_committed(child, TaskId(1)), "disarmed probe is false");
            // Armed: each (child, parent) edge commits at most once, and a
            // duplicate still charges the round trip but moves nothing.
            kv.enable_edge_dedup();
            assert_eq!(kv.incr_edge(key, child, TaskId(2)).await, Some(3));
            let t0 = clock::now();
            assert_eq!(kv.incr_edge(key, child, TaskId(2)).await, None);
            assert_eq!(clock::now() - t0, Duration::from_secs_f64(300.0 * 1e-6) * 2);
            assert_eq!(kv.incr_edge(key, child, TaskId(3)).await, Some(4));
            assert!(kv.edge_committed(child, TaskId(2)));
            assert!(!kv.edge_committed(child, TaskId(4)));
            assert_eq!(kv.counter_value(key), 4);
        });
    }

    #[test]
    fn contains_charges_a_round_trip() {
        crate::rt::run_virtual(async {
            let kv = arena();
            let key = ObjectKey::output(TaskId(5));
            let t0 = clock::now();
            assert!(!kv.contains(key).await, "nothing stored yet");
            let dt = clock::now() - t0;
            // Default config: 300 µs one-way => 600 µs round trip.
            assert_eq!(dt, Duration::from_secs_f64(300.0 * 1e-6) * 2);
        });
    }

    #[test]
    fn contains_escape_hatch_is_free() {
        crate::rt::run_virtual(async {
            let cfg = NetConfig {
                charge_exists: false,
                ..NetConfig::default()
            };
            let kv = KvStore::new(cfg, Arc::new(MetricsHub::new())).arena(JobId(0), 0);
            let key = ObjectKey::output(TaskId(5));
            kv.put(key, DataObj::synthetic(8), 1e9).await;
            let t0 = clock::now();
            assert!(kv.contains(key).await);
            assert_eq!(clock::now(), t0, "charge_exists=false must be free");
            // The sync forensic probe is always free.
            assert!(kv.peek_contains(key));
            assert!(!kv.peek_contains(ObjectKey::output(TaskId(6))));
        });
    }

    #[test]
    fn dense_slots_presize_and_grow() {
        crate::rt::run_virtual(async {
            let kv = arena();
            kv.ensure_task_capacity(16);
            kv.put(ObjectKey::output(TaskId(15)), DataObj::synthetic(1), 1e9)
                .await;
            // Beyond the pre-sized range: the cold growth path.
            kv.put(ObjectKey::output(TaskId(100)), DataObj::synthetic(2), 1e9)
                .await;
            assert_eq!(kv.incr(ObjectKey::counter(TaskId(200))).await, 1);
            assert_eq!(kv.object_count(), 2);
            assert_eq!(
                kv.object_keys(),
                vec!["out:100".to_string(), "out:15".to_string()]
            );
            assert_eq!(kv.counter_entries(), vec![("ctr:200".to_string(), 1)]);
        });
    }

    #[test]
    fn named_keys_use_the_side_map() {
        crate::rt::run_virtual(async {
            let kv = arena();
            let k = ObjectKey::named("forensics:blob");
            kv.put(k, DataObj::synthetic(64), 1e9).await;
            assert!(kv.peek_contains(k));
            assert_eq!(kv.get(k, 1e9).await.unwrap().bytes, 64);
            assert_eq!(kv.incr(ObjectKey::named("forensics:ctr")).await, 1);
            assert_eq!(kv.incr(ObjectKey::named("forensics:ctr")).await, 2);
            assert_eq!(kv.counter_value(ObjectKey::named("forensics:ctr")), 2);
            assert_eq!(kv.object_count(), 1);
            assert!(kv.object_keys()[0].starts_with("key:"));
        });
    }

    #[test]
    fn transfers_cost_virtual_time() {
        crate::rt::run_virtual(async {
            let kv = arena();
            let t0 = clock::now();
            kv.put(
                ObjectKey::output(TaskId(0)),
                DataObj::synthetic(100 * 1024 * 1024),
                75e6, // lambda NIC ~600 Mbps
            )
            .await;
            let dt = clock::now() - t0;
            // 100 MiB at 75 MB/s ≈ 1.4 s — must be visible in virtual time.
            assert!(dt > Duration::from_secs(1), "dt = {dt:?}");
        });
    }

    #[test]
    fn ideal_storage_is_free() {
        crate::rt::run_virtual(async {
            let kv = KvStore::with_ideal(NetConfig::default(), Arc::new(MetricsHub::new()), true)
                .arena(JobId(0), 0);
            let t0 = clock::now();
            kv.put(
                ObjectKey::output(TaskId(0)),
                DataObj::synthetic(1 << 30),
                75e6,
            )
            .await;
            kv.get(ObjectKey::output(TaskId(0)), 75e6).await.unwrap();
            assert!(kv.contains(ObjectKey::output(TaskId(0))).await);
            assert_eq!(clock::now(), t0);
        });
    }

    #[test]
    fn shared_vm_contends() {
        crate::rt::run_virtual(async {
            // With all shards behind one NIC, two large transfers to different
            // keys serialize; with shard-per-VM they proceed in parallel.
            let metrics = Arc::new(MetricsHub::new());
            let mut cfg = NetConfig {
                kv_shared_vm: true,
                kv_latency_us: 0.0,
                ..NetConfig::default()
            };
            cfg.kv_bandwidth_bps = 1e6; // 1 MB/s to make it visible
            let shared = KvStore::new(cfg.clone(), metrics.clone()).arena(JobId(0), 0);
            // Pick two keys that live on *different* shards so that the
            // shard-per-VM configuration can actually parallelize them.
            let (k1, k2) = {
                let probe = KvStore::new(
                    NetConfig {
                        kv_shared_vm: false,
                        ..NetConfig::default()
                    },
                    Arc::new(MetricsHub::new()),
                )
                .arena(JobId(0), 0);
                let mut found = None;
                'outer: for i in 0..32u32 {
                    for j in (i + 1)..32 {
                        let a = ObjectKey::output(TaskId(i));
                        let b = ObjectKey::output(TaskId(j));
                        if !std::ptr::eq(probe.shard_of(a), probe.shard_of(b)) {
                            found = Some((a, b));
                            break 'outer;
                        }
                    }
                }
                found.expect("no shard-distinct key pair in 32 probes")
            };
            let t0 = clock::now();
            crate::rt::join_all(vec![
                shared.put(k1, DataObj::synthetic(1_000_000), 1e9),
                shared.put(k2, DataObj::synthetic(1_000_000), 1e9),
            ])
            .await;
            let shared_dt = clock::now() - t0;

            cfg.kv_shared_vm = false;
            let split = KvStore::new(cfg, metrics).arena(JobId(0), 0);
            let t1 = clock::now();
            crate::rt::join_all(vec![
                split.put(k1, DataObj::synthetic(1_000_000), 1e9),
                split.put(k2, DataObj::synthetic(1_000_000), 1e9),
            ])
            .await;
            let split_dt = clock::now() - t1;
            assert!(
                shared_dt > split_dt,
                "shared {shared_dt:?} vs split {split_dt:?}"
            );
        });
    }

    #[test]
    fn arenas_isolate_objects_and_counters_per_job() {
        // Two jobs over ONE shared cluster store under the SAME packed
        // keys: objects, counters, and forensic views must be fully
        // disjoint — job scope is carried by the arena handle.
        crate::rt::run_virtual(async {
            let store = KvStore::new(NetConfig::default(), Arc::new(MetricsHub::new()));
            let a = store.arena(JobId(1), 8);
            let b = store.arena(JobId(2), 8);
            let key = ObjectKey::output(TaskId(3));
            let ctr = ObjectKey::counter(TaskId(3));

            a.put(key, DataObj::synthetic(111), 1e9).await;
            assert!(a.peek_contains(key));
            assert!(!b.peek_contains(key), "job 2 must not see job 1's object");
            assert!(b.get(key, 1e9).await.is_err());

            b.put(key, DataObj::synthetic(222), 1e9).await;
            assert_eq!(a.get(key, 1e9).await.unwrap().bytes, 111);
            assert_eq!(b.get(key, 1e9).await.unwrap().bytes, 222);

            assert_eq!(a.incr(ctr).await, 1);
            assert_eq!(a.incr(ctr).await, 2);
            assert_eq!(b.incr(ctr).await, 1, "counters are per-job");
            assert_eq!(a.counter_value(ctr), 2);
            assert_eq!(b.counter_value(ctr), 1);

            assert_eq!(a.object_keys(), vec!["out:3".to_string()]);
            assert_eq!(b.object_keys(), vec!["out:3".to_string()]);
            assert_eq!(a.object_count(), 1);
            assert_eq!(b.object_count(), 1);
        });
    }

    #[test]
    fn arena_channels_are_job_scoped() {
        crate::rt::run_virtual(async {
            let store = KvStore::new(NetConfig::default(), Arc::new(MetricsHub::new()));
            let a = store.arena(JobId(1), 0);
            let b = store.arena(JobId(2), 0);
            let mut sub_a = a.subscribe("wukong:final");
            let mut sub_b = b.subscribe("wukong:final");
            assert_eq!(
                a.publish("wukong:final", Message::FinalResult { task: TaskId(1) })
                    .await,
                1,
                "job 1's publish reaches only job 1's subscriber"
            );
            assert_eq!(
                b.publish("wukong:final", Message::FinalResult { task: TaskId(2) })
                    .await,
                1
            );
            assert!(matches!(
                sub_a.recv().await,
                Some(Message::FinalResult { task: TaskId(1) })
            ));
            assert!(matches!(
                sub_b.recv().await,
                Some(Message::FinalResult { task: TaskId(2) })
            ));
        });
    }

    #[test]
    fn job_zero_routing_matches_legacy_shard_hash() {
        // JobId(0)'s shard salt is 0, so its routing must be exactly
        // mix64(key) — the PR-3 single-job behavior, pinned.
        let store = KvStore::new(NetConfig::default(), Arc::new(MetricsHub::new()));
        let arena = store.arena(JobId(0), 0);
        for i in 0..64u32 {
            let key = ObjectKey::output(TaskId(i));
            let legacy = (key.shard_hash() % store.shard_count() as u64) as usize;
            assert!(std::ptr::eq(arena.shard_of(key), &store.shards[legacy]));
        }
    }

    #[test]
    fn resident_ledger_tracks_stores_replaces_and_drops() {
        crate::rt::run_virtual(async {
            let store = KvStore::new(NetConfig::default(), Arc::new(MetricsHub::new()));
            let a = store.arena(JobId(1), 4);
            let b = store.arena(JobId(2), 4);
            a.put(ObjectKey::output(TaskId(0)), DataObj::synthetic(100), 1e9)
                .await;
            a.put(ObjectKey::named("side"), DataObj::synthetic(50), 1e9)
                .await;
            b.put(ObjectKey::output(TaskId(0)), DataObj::synthetic(30), 1e9)
                .await;
            assert_eq!(a.resident_bytes(), 150);
            assert_eq!(a.resident_bytes(), a.stored_bytes());
            assert_eq!(store.resident_kv_bytes(), 180);
            // Replacing an object charges only the delta.
            a.put(ObjectKey::output(TaskId(0)), DataObj::synthetic(40), 1e9)
                .await;
            assert_eq!(a.resident_bytes(), 90);
            assert_eq!(store.resident_kv_bytes(), 120);
            assert_eq!(store.registered_arena_count(), 2);
            // Dropping the last handle settles the ledger + registry.
            drop(a);
            assert_eq!(store.resident_kv_bytes(), 30);
            assert_eq!(store.registered_arena_count(), 1);
            drop(b);
            assert_eq!(store.resident_kv_bytes(), 0);
            assert_eq!(store.registered_arena_count(), 0);
        });
    }

    #[test]
    fn net_bytes_ledger_counts_payload_transfers_only() {
        crate::rt::run_virtual(async {
            let metrics = Arc::new(MetricsHub::new());
            let store = KvStore::new(NetConfig::default(), metrics.clone());
            let arena = store.arena(JobId(1), 4);
            arena
                .put(ObjectKey::output(TaskId(0)), DataObj::synthetic(100), 1e9)
                .await;
            arena.get(ObjectKey::output(TaskId(0)), 1e9).await.unwrap();
            // Control messages carry no payload.
            arena.incr(ObjectKey::counter(TaskId(1))).await;
            arena.contains(ObjectKey::output(TaskId(0))).await;
            assert_eq!(arena.net_bytes_moved(), 200);
            assert_eq!(metrics.net_bytes_moved(), 200);
        });
    }

    #[test]
    fn ideal_store_moves_no_net_bytes() {
        crate::rt::run_virtual(async {
            let metrics = Arc::new(MetricsHub::new());
            let store = KvStore::with_ideal(NetConfig::default(), metrics.clone(), true);
            let arena = store.arena(JobId(1), 4);
            arena
                .put(ObjectKey::output(TaskId(0)), DataObj::synthetic(100), 1e9)
                .await;
            arena.get(ObjectKey::output(TaskId(0)), 1e9).await.unwrap();
            assert_eq!(arena.net_bytes_moved(), 0);
            assert_eq!(metrics.net_bytes_moved(), 0);
        });
    }

    #[test]
    fn budget_eviction_is_oldest_finished_first_and_spares_running_jobs() {
        crate::rt::run_virtual(async {
            let store = KvStore::new(NetConfig::default(), Arc::new(MetricsHub::new()));
            let a = store.arena(JobId(1), 2);
            let b = store.arena(JobId(2), 2);
            let c = store.arena(JobId(3), 2);
            for (arena, bytes) in [(&a, 100u64), (&b, 100), (&c, 100)] {
                arena
                    .put(ObjectKey::output(TaskId(0)), DataObj::synthetic(bytes), 1e9)
                    .await;
            }
            assert_eq!(store.resident_kv_bytes(), 300);

            // Nothing retired yet: running jobs are never evicted, even
            // far over budget.
            assert!(store.enforce_kv_budget(0).is_empty());
            assert_eq!(store.resident_kv_bytes(), 300);

            // Retire 2 then 1 (retired bytes = 200; running job 3's 100
            // bytes do NOT count against the budget). Budget 150 evicts
            // exactly the OLDEST finished (job 2), not job 1.
            store.retire(JobId(2));
            store.retire(JobId(1));
            assert_eq!(store.enforce_kv_budget(150), vec![JobId(2)]);
            assert_eq!(store.resident_kv_bytes(), 200);
            assert_eq!(b.resident_bytes(), 0);
            assert_eq!(b.object_count(), 0);
            assert_eq!(a.resident_bytes(), 100, "job 1 retained under budget");
            // Retained (100) <= budget even though total resident (200,
            // incl. the running job) exceeds it: re-enforcing changes
            // nothing — live jobs are outside the budget.
            assert!(store.enforce_kv_budget(150).is_empty());

            // Budget 0 retains nothing: job 1 goes too; running job 3
            // survives.
            assert_eq!(store.enforce_kv_budget(0), vec![JobId(1)]);
            assert_eq!(store.resident_kv_bytes(), 100);
            assert_eq!(store.registered_arena_count(), 1);
            assert!(c.peek_contains(ObjectKey::output(TaskId(0))));
        });
    }

    #[test]
    fn retire_is_idempotent_and_tears_down_channels() {
        crate::rt::run_virtual(async {
            let store = KvStore::new(NetConfig::default(), Arc::new(MetricsHub::new()));
            let a = store.arena(JobId(7), 2);
            let _sub = a.subscribe("wukong:final");
            assert_eq!(store.pubsub_namespace_count(), 1);
            store.retire(JobId(7));
            store.retire(JobId(7)); // idempotent
            assert_eq!(store.pubsub_namespace_count(), 0);
            a.put(ObjectKey::output(TaskId(1)), DataObj::synthetic(8), 1e9)
                .await;
            assert_eq!(store.enforce_kv_budget(0), vec![JobId(7)]);
            assert_eq!(store.registered_arena_count(), 0);
            assert_eq!(store.resident_kv_bytes(), 0);
        });
    }

    fn spill_store(metrics: Arc<MetricsHub>) -> Arc<KvStore> {
        KvStore::with_spill(
            NetConfig::default(),
            FaultConfig::default(),
            metrics,
            false,
            SpillConfig {
                enabled: true,
                ..SpillConfig::default()
            },
        )
    }

    #[test]
    fn late_get_after_eviction_is_served_cold_from_the_spill_tier() {
        crate::rt::run_virtual(async {
            let metrics = Arc::new(MetricsHub::new());
            let store = spill_store(metrics.clone());
            let a = store.arena(JobId(1), 2);
            let key = ObjectKey::output(TaskId(0));
            // 90 MB: exactly 1 s of streaming at the default 90 MB/s tier.
            a.put(key, DataObj::synthetic(90_000_000), 1e9).await;
            let put_bytes = a.net_bytes_moved();
            store.retire(JobId(1));
            assert_eq!(store.enforce_kv_budget(0), vec![JobId(1)]);
            // The KV cluster is empty — the payload moved, not died.
            assert_eq!(a.resident_bytes(), 0);
            assert_eq!(store.resident_kv_bytes(), 0);
            assert!(!a.peek_contains(key));
            assert_eq!(store.spill().live_bytes(), 90_000_000);
            assert_eq!(metrics.spill_bytes_demoted(), 90_000_000);
            // Demotion itself counted as traffic (KV shard -> cold store).
            assert_eq!(a.net_bytes_moved(), put_bytes + 90_000_000);

            // The late get succeeds at cold prices: 15 ms TTFB + 1 s
            // streaming (benign faults: the tail is pass-through).
            let t0 = clock::now();
            let obj = a.get(key, 1e9).await.unwrap();
            let dt = clock::now() - t0;
            assert_eq!(obj.bytes, 90_000_000);
            assert_eq!(
                dt,
                Duration::from_millis(15) + Duration::from_secs(1),
                "cold penalty must be charged"
            );
            assert_eq!(metrics.spill_reads(), 1);
            assert_eq!(metrics.spill_bytes_read(), 90_000_000);
            assert_eq!(a.net_bytes_moved(), put_bytes + 2 * 90_000_000);
            // Never-stored keys still error.
            assert!(matches!(
                a.get(ObjectKey::output(TaskId(1)), 1e9).await.unwrap_err(),
                EngineError::MissingObject { .. }
            ));
        });
    }

    #[test]
    fn repeated_cold_reads_promote_back_to_the_warm_tier() {
        crate::rt::run_virtual(async {
            let metrics = Arc::new(MetricsHub::new());
            let store = KvStore::with_spill(
                NetConfig::default(),
                FaultConfig::default(),
                metrics.clone(),
                false,
                SpillConfig {
                    enabled: true,
                    promote_after_reads: 2,
                    ..SpillConfig::default()
                },
            );
            let a = store.arena(JobId(1), 2);
            let key = ObjectKey::output(TaskId(0));
            a.put(key, DataObj::synthetic(90_000_000), 1e9).await;
            store.retire(JobId(1));
            assert_eq!(store.enforce_kv_budget(0), vec![JobId(1)]);
            assert!(!a.peek_contains(key));

            // First cold read: served cold, object stays parked.
            a.get(key, 1e9).await.unwrap();
            assert_eq!(metrics.spill_promotions(), 0);
            assert!(!a.peek_contains(key));

            // Second cold read hits the threshold: the object leaves the
            // tier and re-enters the arena warm. The promoting read
            // itself is still cold-priced (15 ms TTFB + 1 s streaming).
            let t0 = clock::now();
            a.get(key, 1e9).await.unwrap();
            assert_eq!(
                clock::now() - t0,
                Duration::from_millis(15) + Duration::from_secs(1)
            );
            assert_eq!(metrics.spill_promotions(), 1);
            assert!(a.peek_contains(key));
            assert_eq!(store.spill().live_bytes(), 0);
            assert_eq!(a.resident_bytes(), 90_000_000);

            // Further reads are warm — the cold-read meter stops.
            let obj = a.get(key, 1e9).await.unwrap();
            assert_eq!(obj.bytes, 90_000_000);
            assert_eq!(metrics.spill_reads(), 2, "no third cold read");
        });
    }

    #[test]
    fn spill_off_eviction_stays_destruction() {
        crate::rt::run_virtual(async {
            let a = arena(); // default store: spill disabled
            let key = ObjectKey::output(TaskId(0));
            a.put(key, DataObj::synthetic(64), 1e9).await;
            a.store().retire(JobId(0));
            assert_eq!(a.store().enforce_kv_budget(0), vec![JobId(0)]);
            assert_eq!(a.store().spill().live_bytes(), 0);
            assert!(matches!(
                a.get(key, 1e9).await.unwrap_err(),
                EngineError::MissingObject { .. }
            ));
        });
    }

    #[test]
    fn drop_without_retire_settles_the_spill_ledger() {
        let metrics = Arc::new(MetricsHub::new());
        let (store, arena) = crate::rt::run_virtual({
            let metrics = metrics.clone();
            async move {
                let store = spill_store(metrics);
                let a = store.arena(JobId(1), 2);
                // 2 GB so the storage-seconds accrual is a round number.
                a.put(ObjectKey::output(TaskId(0)), DataObj::synthetic(2_000_000_000), 1e9)
                    .await;
                store.retire(JobId(1));
                store.enforce_kv_budget(0);
                let demoted_at = clock::now();
                clock::sleep(Duration::from_secs(5)).await;
                // The cold read advances the tier's high-water mark 5 s
                // past demotion.
                a.get(ObjectKey::output(TaskId(0)), 1e9).await.unwrap();
                assert!(clock::now() - demoted_at > Duration::from_secs(5));
                (store, a)
            }
        });
        // Drop OUTSIDE the virtual-time executor — no explicit purge ran.
        // The arena's Drop must settle the spill set (at the high-water
        // mark) without touching the (absent) clock.
        assert_eq!(store.spill().live_bytes(), 2_000_000_000);
        drop(arena);
        assert_eq!(store.spill().live_bytes(), 0);
        // 2 GB held >= 5 s (demote -> last cold read) = >= 10 GB-seconds.
        assert!(
            store.spill().settled_gb_seconds() >= 10.0,
            "settled {} GB-s",
            store.spill().settled_gb_seconds()
        );
        assert!(store.spill().settled_cost_usd() > 0.0);
    }

    #[test]
    fn spill_billing_closes_to_zero_after_purge() {
        crate::rt::run_virtual(async {
            let store = spill_store(Arc::new(MetricsHub::new()));
            let a = store.arena(JobId(1), 2);
            a.put(ObjectKey::output(TaskId(0)), DataObj::synthetic(1_000_000_000), 1e9)
                .await;
            store.retire(JobId(1));
            store.enforce_kv_budget(0);
            clock::sleep(Duration::from_secs(10)).await;
            let now = clock::now();
            assert!(store.spill().live_gb_seconds(now) > 9.9);
            let bills = store.spill().purge_all(now);
            assert_eq!(bills.len(), 1);
            assert_eq!(bills[0].job, 1);
            assert_eq!(bills[0].bytes, 1_000_000_000);
            assert!((bills[0].gb_seconds - store.spill().settled_gb_seconds()).abs() < 1e-12);
            assert_eq!(store.spill().live_gb_seconds(now), 0.0);
            assert_eq!(store.spill().live_bytes(), 0);
            // Purged means gone: the late get is a real miss again.
            assert!(a.get(ObjectKey::output(TaskId(0)), 1e9).await.is_err());
            // Arena drop after the purge double-settles nothing.
            let settled = store.spill().settled_gb_seconds();
            drop(a);
            assert_eq!(store.spill().settled_gb_seconds(), settled);
        });
    }

    #[test]
    fn forensics_snapshot_survives_eviction() {
        crate::rt::run_virtual(async {
            let store = KvStore::new(NetConfig::default(), Arc::new(MetricsHub::new()));
            let a = store.arena(JobId(1), 4);
            a.put(ObjectKey::output(TaskId(2)), DataObj::synthetic(64), 1e9)
                .await;
            a.incr(ObjectKey::counter(TaskId(3))).await;
            let snap = a.forensics();
            assert_eq!(snap.object_keys, vec!["out:2".to_string()]);
            assert_eq!(snap.counter_entries, vec![("ctr:3".to_string(), 1)]);
            assert_eq!(snap.resident_bytes, 64);
            store.retire(JobId(1));
            store.enforce_kv_budget(0);
            // The live arena is empty, the snapshot is not.
            assert_eq!(a.object_count(), 0);
            assert_eq!(a.resident_bytes(), 0);
            assert!(a.counter_entries().is_empty());
            assert_eq!(snap.object_keys.len(), 1);
        });
    }
}
