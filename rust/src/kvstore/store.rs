//! The sharded KV store itself: put/get of data objects, atomic counters
//! (fan-in dependency counters, paper §IV-C), and the pub/sub front end.

use crate::compute::DataObj;
use crate::core::{clock, EngineError, EngineResult, FaultConfig, NetConfig, ObjectKey};
use crate::kvstore::netmodel::{Nic, TailLatency};
use crate::kvstore::pubsub::{Message, PubSub, Subscription};
use crate::metrics::{KvOpKind, MetricsHub};
use std::collections::HashMap;
use std::sync::{Arc, Mutex};
use std::time::Duration;

struct Shard {
    objects: Mutex<HashMap<String, DataObj>>,
    counters: Mutex<HashMap<String, u64>>,
    nic: Arc<Nic>,
}

/// The KV store cluster. Cloneable by `Arc`.
pub struct KvStore {
    shards: Vec<Shard>,
    pubsub: PubSub,
    cfg: NetConfig,
    metrics: Arc<MetricsHub>,
    /// Seeded heavy-tail latency injection (pass-through when benign).
    tail: TailLatency,
    /// "Ideal storage" mode (Fig. 10 yellow bars): data still flows so
    /// real-compute jobs stay correct, but every transfer is free.
    ideal: bool,
}

impl KvStore {
    pub fn new(cfg: NetConfig, metrics: Arc<MetricsHub>) -> Arc<Self> {
        Self::with_ideal(cfg, metrics, false)
    }

    pub fn with_ideal(cfg: NetConfig, metrics: Arc<MetricsHub>, ideal: bool) -> Arc<Self> {
        Self::with_faults(cfg, FaultConfig::default(), metrics, ideal)
    }

    /// Full constructor: network config, fault-injection profile, ideal
    /// mode. Fault draws are seeded, so identical runs sample identical
    /// latency tails.
    pub fn with_faults(
        cfg: NetConfig,
        faults: FaultConfig,
        metrics: Arc<MetricsHub>,
        ideal: bool,
    ) -> Arc<Self> {
        assert!(cfg.kv_shards > 0);
        // Shard-per-VM: each shard gets its own NIC. Shared-VM mode (the
        // pre-optimization configuration of Fig. 12): one NIC serves all
        // shards, so bursts contend.
        let shared: Option<Arc<Nic>> = if cfg.kv_shared_vm {
            Some(Nic::new(cfg.kv_bandwidth_bps))
        } else {
            None
        };
        let shards = (0..cfg.kv_shards)
            .map(|_| Shard {
                objects: Mutex::new(HashMap::new()),
                counters: Mutex::new(HashMap::new()),
                nic: shared
                    .clone()
                    .unwrap_or_else(|| Nic::new(cfg.kv_bandwidth_bps)),
            })
            .collect();
        Arc::new(KvStore {
            shards,
            pubsub: PubSub::new(),
            cfg,
            metrics,
            tail: TailLatency::from_faults(&faults, 0x6b76),
            ideal,
        })
    }

    fn shard_of(&self, key: &str) -> &Shard {
        // FNV-1a — stable, dependency-free key hashing.
        let h = crate::core::Fnv1a::hash(key.as_bytes());
        &self.shards[(h % self.shards.len() as u64) as usize]
    }

    fn latency(&self) -> Duration {
        Duration::from_secs_f64(self.cfg.kv_latency_us * 1e-6)
    }

    /// Stores `obj` under `key`, charging latency + bandwidth.
    pub async fn put(&self, key: &ObjectKey, obj: DataObj, client_bps: f64) {
        let t0 = clock::now();
        let bytes = obj.bytes;
        let shard = self.shard_of(key.as_str());
        if !self.ideal {
            clock::sleep(self.tail.sample(self.latency())).await;
            shard.nic.transfer_capped(bytes, client_bps).await;
        }
        shard
            .objects
            .lock()
            .unwrap()
            .insert(key.as_str().to_string(), obj);
        self.metrics
            .record_kv_op(KvOpKind::Write, bytes, clock::now() - t0);
    }

    /// Retrieves the object under `key`, charging latency + bandwidth.
    pub async fn get(&self, key: &ObjectKey, client_bps: f64) -> EngineResult<DataObj> {
        let t0 = clock::now();
        let shard = self.shard_of(key.as_str());
        let obj = shard
            .objects
            .lock()
            .unwrap()
            .get(key.as_str())
            .cloned()
            .ok_or_else(|| EngineError::MissingObject {
                key: key.as_str().to_string(),
            })?;
        if !self.ideal {
            clock::sleep(self.tail.sample(self.latency())).await;
            shard.nic.transfer_capped(obj.bytes, client_bps).await;
        }
        self.metrics
            .record_kv_op(KvOpKind::Read, obj.bytes, clock::now() - t0);
        Ok(obj)
    }

    /// Checks existence without transferring the value.
    pub fn contains(&self, key: &ObjectKey) -> bool {
        self.shard_of(key.as_str())
            .objects
            .lock()
            .unwrap()
            .contains_key(key.as_str())
    }

    /// Atomically increments the counter at `key` and returns the new
    /// value (Redis INCR — the fan-in dependency counter of paper §IV-C).
    /// Small fixed-size message: round-trip latency only.
    pub async fn incr(&self, key: &ObjectKey) -> u64 {
        let t0 = clock::now();
        if !self.ideal {
            clock::sleep(self.tail.sample(self.latency() * 2)).await; // request + reply
        }
        let shard = self.shard_of(key.as_str());
        let v = {
            let mut counters = shard.counters.lock().unwrap();
            let e = counters.entry(key.as_str().to_string()).or_insert(0);
            *e += 1;
            *e
        };
        self.metrics
            .record_kv_op(KvOpKind::Incr, 0, clock::now() - t0);
        v
    }

    /// Reads a counter without incrementing (tests / debugging).
    pub fn counter_value(&self, key: &ObjectKey) -> u64 {
        *self
            .shard_of(key.as_str())
            .counters
            .lock()
            .unwrap()
            .get(key.as_str())
            .unwrap_or(&0)
    }

    /// Publishes `msg` on `channel` with pub/sub delivery latency.
    pub async fn publish(&self, channel: &str, msg: Message) -> usize {
        let t0 = clock::now();
        if !self.ideal {
            clock::sleep(
                self.tail
                    .sample(Duration::from_secs_f64(self.cfg.pubsub_latency_us * 1e-6)),
            )
            .await;
        }
        let n = self.pubsub.publish(channel, msg);
        self.metrics
            .record_kv_op(KvOpKind::Publish, 0, clock::now() - t0);
        n
    }

    /// Subscribes to `channel` (no modeled cost: subscriptions are set up
    /// once at job start, like Dask's cluster-init connections).
    pub fn subscribe(&self, channel: &str) -> Subscription {
        self.pubsub.subscribe(channel)
    }

    /// Number of stored objects across all shards (tests / reports).
    pub fn object_count(&self) -> usize {
        self.shards
            .iter()
            .map(|s| s.objects.lock().unwrap().len())
            .sum()
    }

    /// Every stored object key across all shards, sorted (forensic
    /// inspection: the differential oracle checks for orphaned
    /// intermediates after a job completes).
    pub fn object_keys(&self) -> Vec<String> {
        let mut keys: Vec<String> = self
            .shards
            .iter()
            .flat_map(|s| s.objects.lock().unwrap().keys().cloned().collect::<Vec<_>>())
            .collect();
        keys.sort();
        keys
    }

    /// Every counter and its final value, sorted by key (forensic
    /// inspection: fan-in counters must end exactly at in-degree).
    pub fn counter_entries(&self) -> Vec<(String, u64)> {
        let mut entries: Vec<(String, u64)> = self
            .shards
            .iter()
            .flat_map(|s| {
                s.counters
                    .lock()
                    .unwrap()
                    .iter()
                    .map(|(k, v)| (k.clone(), *v))
                    .collect::<Vec<_>>()
            })
            .collect();
        entries.sort();
        entries
    }

    /// Total stored bytes across all shards.
    pub fn stored_bytes(&self) -> u64 {
        self.shards
            .iter()
            .map(|s| {
                s.objects
                    .lock()
                    .unwrap()
                    .values()
                    .map(|o| o.bytes)
                    .sum::<u64>()
            })
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::TaskId;

    fn store() -> Arc<KvStore> {
        KvStore::new(NetConfig::default(), Arc::new(MetricsHub::new()))
    }

    #[test]
    fn put_get_roundtrip() {
        crate::rt::run_virtual(async {
            let kv = store();
            let key = ObjectKey::output(TaskId(1));
            kv.put(&key, DataObj::synthetic(1024), 1e9).await;
            let obj = kv.get(&key, 1e9).await.unwrap();
            assert_eq!(obj.bytes, 1024);
            assert_eq!(kv.object_count(), 1);
            assert_eq!(kv.stored_bytes(), 1024);
        });
    }

    #[test]
    fn missing_key_errors() {
        crate::rt::run_virtual(async {
            let kv = store();
            let err = kv.get(&ObjectKey::output(TaskId(9)), 1e9).await.unwrap_err();
            assert!(matches!(err, EngineError::MissingObject { .. }));
        });
    }

    #[test]
    fn incr_is_atomic_and_monotonic() {
        crate::rt::run_virtual(async {
            let kv = store();
            let key = ObjectKey::counter(TaskId(3));
            assert_eq!(kv.incr(&key).await, 1);
            assert_eq!(kv.incr(&key).await, 2);
            assert_eq!(kv.incr(&key).await, 3);
            assert_eq!(kv.counter_value(&key), 3);
        });
    }

    #[test]
    fn transfers_cost_virtual_time() {
        crate::rt::run_virtual(async {
            let kv = store();
            let t0 = clock::now();
            kv.put(
                &ObjectKey::output(TaskId(0)),
                DataObj::synthetic(100 * 1024 * 1024),
                75e6, // lambda NIC ~600 Mbps
            )
            .await;
            let dt = clock::now() - t0;
            // 100 MiB at 75 MB/s ≈ 1.4 s — must be visible in virtual time.
            assert!(dt > Duration::from_secs(1), "dt = {dt:?}");
        });
    }

    #[test]
    fn ideal_storage_is_free() {
        crate::rt::run_virtual(async {
            let kv = KvStore::with_ideal(NetConfig::default(), Arc::new(MetricsHub::new()), true);
            let t0 = clock::now();
            kv.put(
                &ObjectKey::output(TaskId(0)),
                DataObj::synthetic(1 << 30),
                75e6,
            )
            .await;
            kv.get(&ObjectKey::output(TaskId(0)), 75e6).await.unwrap();
            assert_eq!(clock::now(), t0);
        });
    }

    #[test]
    fn shared_vm_contends() {
        crate::rt::run_virtual(async {
            // With all shards behind one NIC, two large transfers to different
            // keys serialize; with shard-per-VM they proceed in parallel.
            let metrics = Arc::new(MetricsHub::new());
            let mut cfg = NetConfig {
                kv_shared_vm: true,
                kv_latency_us: 0.0,
                ..NetConfig::default()
            };
            cfg.kv_bandwidth_bps = 1e6; // 1 MB/s to make it visible
            let shared = KvStore::new(cfg.clone(), metrics.clone());
            // Pick two keys that live on *different* shards so that the
            // shard-per-VM configuration can actually parallelize them.
            let (k1, k2) = {
                let probe = KvStore::new(
                    NetConfig {
                        kv_shared_vm: false,
                        ..NetConfig::default()
                    },
                    Arc::new(MetricsHub::new()),
                );
                let mut found = None;
                'outer: for i in 0..32 {
                    for j in (i + 1)..32 {
                        let a = format!("key{i}");
                        let b = format!("key{j}");
                        if !std::ptr::eq(probe.shard_of(&a), probe.shard_of(&b)) {
                            found = Some((a, b));
                            break 'outer;
                        }
                    }
                }
                found.expect("no shard-distinct key pair in 32 probes")
            };
            let t0 = clock::now();
            crate::rt::join_all(vec![
                shared.put(&ObjectKey(k1.clone()), DataObj::synthetic(1_000_000), 1e9),
                shared.put(&ObjectKey(k2.clone()), DataObj::synthetic(1_000_000), 1e9),
            ])
            .await;
            let shared_dt = clock::now() - t0;

            cfg.kv_shared_vm = false;
            let split = KvStore::new(cfg, metrics);
            let t1 = clock::now();
            crate::rt::join_all(vec![
                split.put(&ObjectKey(k1), DataObj::synthetic(1_000_000), 1e9),
                split.put(&ObjectKey(k2), DataObj::synthetic(1_000_000), 1e9),
            ])
            .await;
            let split_dt = clock::now() - t1;
            assert!(
                shared_dt > split_dt,
                "shared {shared_dt:?} vs split {split_dt:?}"
            );
        });
    }
}
