//! The sharded KV store itself: put/get of data objects, atomic counters
//! (fan-in dependency counters, paper §IV-C), and the pub/sub front end.
//!
//! ## Hot-path memory layout
//!
//! Keys are packed `u64`s ([`ObjectKey`]) and the store is backed by
//! **dense per-DAG slot storage**: task outputs live in a
//! `Vec<Mutex<Option<DataObj>>>` and fan-in counters in a
//! `Vec<AtomicU64>`, both indexed directly by `TaskId` and sized once at
//! job start ([`KvStore::ensure_task_capacity`]). `get`/`put`/`contains`
//! are slot lookups and `incr` is a single `fetch_add` — no `String`
//! allocation, no byte hashing, and no map mutex anywhere on the
//! task-output/counter path. Shards exist purely as network endpoints
//! (NIC queues); routing is an integer mix of the packed key.
//!
//! Keys outside the task range ([`ObjectKey::named`]) go to a small
//! hash-keyed side map, and the forensic/introspection API
//! ([`KvStore::object_keys`] / [`KvStore::counter_entries`]) renders key
//! strings lazily via `Display`, byte-identical to the strings the
//! pre-packing implementation stored.

use crate::compute::DataObj;
use crate::core::{clock, EngineError, EngineResult, FaultConfig, JobId, NetConfig, ObjectKey};
use crate::kvstore::netmodel::{Nic, TailLatency};
use crate::kvstore::pubsub::{Message, PubSub, Subscription};
use crate::metrics::{KvOpKind, MetricsHub};
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, RwLock};
use std::time::Duration;

/// One shard: a network endpoint. All data lives in the dense slot arrays
/// of the store; the shard contributes only its NIC (latency/bandwidth
/// queueing).
struct Shard {
    nic: Arc<Nic>,
}

/// Dense per-DAG slot storage, indexed by `TaskId`. Sized once at job
/// start; growth after that is a cold path taken only by tests that
/// store ad-hoc keys.
#[derive(Default)]
struct TaskSlots {
    objects: Vec<Mutex<Option<DataObj>>>,
    counters: Vec<AtomicU64>,
}

/// The KV store cluster. Cloneable by `Arc`.
pub struct KvStore {
    shards: Vec<Shard>,
    /// Dense task-output / fan-in-counter slots (the hot path).
    slots: RwLock<TaskSlots>,
    /// Side maps for the namespaced non-task key range, keyed by the
    /// packed key word.
    named_objects: Mutex<HashMap<u64, DataObj>>,
    named_counters: Mutex<HashMap<u64, u64>>,
    pubsub: PubSub,
    cfg: NetConfig,
    metrics: Arc<MetricsHub>,
    /// Seeded heavy-tail latency injection (pass-through when benign).
    tail: TailLatency,
    /// "Ideal storage" mode (Fig. 10 yellow bars): data still flows so
    /// real-compute jobs stay correct, but every transfer is free.
    ideal: bool,
}

impl KvStore {
    pub fn new(cfg: NetConfig, metrics: Arc<MetricsHub>) -> Arc<Self> {
        Self::with_ideal(cfg, metrics, false)
    }

    pub fn with_ideal(cfg: NetConfig, metrics: Arc<MetricsHub>, ideal: bool) -> Arc<Self> {
        Self::with_faults(cfg, FaultConfig::default(), metrics, ideal)
    }

    /// Full constructor: network config, fault-injection profile, ideal
    /// mode. Fault draws are seeded, so identical runs sample identical
    /// latency tails.
    pub fn with_faults(
        cfg: NetConfig,
        faults: FaultConfig,
        metrics: Arc<MetricsHub>,
        ideal: bool,
    ) -> Arc<Self> {
        assert!(cfg.kv_shards > 0);
        // Shard-per-VM: each shard gets its own NIC. Shared-VM mode (the
        // pre-optimization configuration of Fig. 12): one NIC serves all
        // shards, so bursts contend.
        let shared: Option<Arc<Nic>> = if cfg.kv_shared_vm {
            Some(Nic::new(cfg.kv_bandwidth_bps))
        } else {
            None
        };
        let shards = (0..cfg.kv_shards)
            .map(|_| Shard {
                nic: shared
                    .clone()
                    .unwrap_or_else(|| Nic::new(cfg.kv_bandwidth_bps)),
            })
            .collect();
        Arc::new(KvStore {
            shards,
            slots: RwLock::new(TaskSlots::default()),
            named_objects: Mutex::new(HashMap::new()),
            named_counters: Mutex::new(HashMap::new()),
            pubsub: PubSub::new(),
            cfg,
            metrics,
            tail: TailLatency::from_faults(&faults, 0x6b76),
            ideal,
        })
    }

    /// Pre-sizes the dense slot storage for a DAG of `n` tasks. The
    /// engines call this once at job start (the DAG size is always known
    /// up front), so every subsequent task-key operation is a pure index
    /// lookup with no growth check taken.
    pub fn ensure_task_capacity(&self, n: usize) {
        {
            let r = self.slots.read().unwrap();
            if r.objects.len() >= n && r.counters.len() >= n {
                return;
            }
        }
        let mut w = self.slots.write().unwrap();
        while w.objects.len() < n {
            w.objects.push(Mutex::new(None));
        }
        while w.counters.len() < n {
            w.counters.push(AtomicU64::new(0));
        }
    }

    fn shard_of(&self, key: ObjectKey) -> &Shard {
        &self.shards[(key.shard_hash() % self.shards.len() as u64) as usize]
    }

    fn latency(&self) -> Duration {
        Duration::from_secs_f64(self.cfg.kv_latency_us * 1e-6)
    }

    /// Writes `obj` into the slot / side map for `key` (no modeled cost).
    fn store_obj(&self, key: ObjectKey, obj: DataObj) {
        match key.object_slot() {
            Some(i) => {
                // `take()` keeps the value re-armable across the (at most
                // one) growth retry without moving out of a loop.
                let mut obj = Some(obj);
                loop {
                    {
                        let slots = self.slots.read().unwrap();
                        if let Some(slot) = slots.objects.get(i) {
                            *slot.lock().unwrap() = obj.take();
                            return;
                        }
                    }
                    self.ensure_task_capacity(i + 1);
                }
            }
            None => {
                self.named_objects.lock().unwrap().insert(key.raw(), obj);
            }
        }
    }

    /// Reads the object for `key` (no modeled cost).
    fn load_obj(&self, key: ObjectKey) -> Option<DataObj> {
        match key.object_slot() {
            Some(i) => {
                let slots = self.slots.read().unwrap();
                slots.objects.get(i)?.lock().unwrap().clone()
            }
            None => self.named_objects.lock().unwrap().get(&key.raw()).cloned(),
        }
    }

    /// Stores `obj` under `key`, charging latency + bandwidth.
    pub async fn put(&self, key: ObjectKey, obj: DataObj, client_bps: f64) {
        let t0 = clock::now();
        let bytes = obj.bytes;
        let shard = self.shard_of(key);
        if !self.ideal {
            clock::sleep(self.tail.sample(self.latency())).await;
            shard.nic.transfer_capped(bytes, client_bps).await;
        }
        self.store_obj(key, obj);
        self.metrics
            .record_kv_op(KvOpKind::Write, bytes, clock::now() - t0);
    }

    /// Retrieves the object under `key`, charging latency + bandwidth.
    pub async fn get(&self, key: ObjectKey, client_bps: f64) -> EngineResult<DataObj> {
        let t0 = clock::now();
        let shard = self.shard_of(key);
        let obj = self
            .load_obj(key)
            .ok_or_else(|| EngineError::MissingObject {
                key: key.to_string(),
            })?;
        if !self.ideal {
            clock::sleep(self.tail.sample(self.latency())).await;
            shard.nic.transfer_capped(obj.bytes, client_bps).await;
        }
        self.metrics
            .record_kv_op(KvOpKind::Read, obj.bytes, clock::now() - t0);
        Ok(obj)
    }

    /// Checks existence without transferring the value. An EXISTS is a
    /// real round trip on a real Redis, so it is charged request + reply
    /// latency like `incr` — unless the `NetConfig::charge_exists` escape
    /// hatch is off (or the store is ideal).
    pub async fn contains(&self, key: ObjectKey) -> bool {
        let t0 = clock::now();
        if !self.ideal && self.cfg.charge_exists {
            clock::sleep(self.tail.sample(self.latency() * 2)).await; // request + reply
        }
        let hit = self.peek_contains(key);
        self.metrics
            .record_kv_op(KvOpKind::Exists, 0, clock::now() - t0);
        hit
    }

    /// Free, synchronous existence probe for forensic/post-mortem checks
    /// (the differential oracle, tests) — never touches virtual time and
    /// records no metrics.
    pub fn peek_contains(&self, key: ObjectKey) -> bool {
        match key.object_slot() {
            Some(i) => {
                let slots = self.slots.read().unwrap();
                slots
                    .objects
                    .get(i)
                    .is_some_and(|slot| slot.lock().unwrap().is_some())
            }
            None => self.named_objects.lock().unwrap().contains_key(&key.raw()),
        }
    }

    /// Atomically increments the counter at `key` and returns the new
    /// value (Redis INCR — the fan-in dependency counter of paper §IV-C).
    /// Small fixed-size message: round-trip latency only. On the
    /// task-counter path this is one `fetch_add` on a dense slot — no
    /// mutex, no allocation.
    pub async fn incr(&self, key: ObjectKey) -> u64 {
        let t0 = clock::now();
        if !self.ideal {
            clock::sleep(self.tail.sample(self.latency() * 2)).await; // request + reply
        }
        let v = match key.counter_slot() {
            Some(i) => loop {
                {
                    let slots = self.slots.read().unwrap();
                    if let Some(c) = slots.counters.get(i) {
                        break c.fetch_add(1, Ordering::Relaxed) + 1;
                    }
                }
                self.ensure_task_capacity(i + 1);
            },
            None => {
                let mut m = self.named_counters.lock().unwrap();
                let e = m.entry(key.raw()).or_insert(0);
                *e += 1;
                *e
            }
        };
        self.metrics
            .record_kv_op(KvOpKind::Incr, 0, clock::now() - t0);
        v
    }

    /// Reads a counter without incrementing (tests / debugging).
    pub fn counter_value(&self, key: ObjectKey) -> u64 {
        match key.counter_slot() {
            Some(i) => {
                let slots = self.slots.read().unwrap();
                slots
                    .counters
                    .get(i)
                    .map_or(0, |c| c.load(Ordering::Relaxed))
            }
            None => *self
                .named_counters
                .lock()
                .unwrap()
                .get(&key.raw())
                .unwrap_or(&0),
        }
    }

    /// Publishes `msg` on `job`'s `channel` with pub/sub delivery latency.
    /// Channels are namespaced per job (see [`PubSub`]), so concurrent
    /// jobs sharing well-known channel names never cross-deliver.
    pub async fn publish(&self, job: JobId, channel: &str, msg: Message) -> usize {
        let t0 = clock::now();
        if !self.ideal {
            clock::sleep(
                self.tail
                    .sample(Duration::from_secs_f64(self.cfg.pubsub_latency_us * 1e-6)),
            )
            .await;
        }
        let n = self.pubsub.publish(job, channel, msg);
        self.metrics
            .record_kv_op(KvOpKind::Publish, 0, clock::now() - t0);
        n
    }

    /// Subscribes to `job`'s `channel` (no modeled cost: subscriptions are
    /// set up once at job start, like Dask's cluster-init connections).
    pub fn subscribe(&self, job: JobId, channel: &str) -> Subscription {
        self.pubsub.subscribe(job, channel)
    }

    /// Tears down `job`'s pub/sub namespace (job complete). Keeps the
    /// broker bounded when many jobs stream through one shared store.
    pub fn remove_job_channels(&self, job: JobId) {
        self.pubsub.remove_job(job);
    }

    /// Number of stored objects (tests / reports).
    pub fn object_count(&self) -> usize {
        let slots = self.slots.read().unwrap();
        let dense = slots
            .objects
            .iter()
            .filter(|slot| slot.lock().unwrap().is_some())
            .count();
        dense + self.named_objects.lock().unwrap().len()
    }

    /// Every stored object key, rendered and sorted (forensic inspection:
    /// the differential oracle checks for orphaned intermediates after a
    /// job completes). Rendering is lazy `Display` of the packed keys —
    /// byte-identical to the strings the pre-packing store held.
    pub fn object_keys(&self) -> Vec<String> {
        let mut keys: Vec<String> = {
            let slots = self.slots.read().unwrap();
            slots
                .objects
                .iter()
                .enumerate()
                .filter(|(_, slot)| slot.lock().unwrap().is_some())
                .map(|(i, _)| ObjectKey::output(crate::core::TaskId(i as u32)).to_string())
                .collect()
        };
        keys.extend(
            self.named_objects
                .lock()
                .unwrap()
                .keys()
                .map(|&raw| ObjectKey::from_raw(raw).to_string()),
        );
        keys.sort();
        keys
    }

    /// Every counter and its final value, sorted by rendered key
    /// (forensic inspection: fan-in counters must end exactly at
    /// in-degree). Zero-valued dense slots are "absent" counters.
    pub fn counter_entries(&self) -> Vec<(String, u64)> {
        let mut entries: Vec<(String, u64)> = {
            let slots = self.slots.read().unwrap();
            slots
                .counters
                .iter()
                .enumerate()
                .filter_map(|(i, c)| {
                    let v = c.load(Ordering::Relaxed);
                    (v > 0).then(|| {
                        (
                            ObjectKey::counter(crate::core::TaskId(i as u32)).to_string(),
                            v,
                        )
                    })
                })
                .collect()
        };
        entries.extend(
            self.named_counters
                .lock()
                .unwrap()
                .iter()
                .map(|(&raw, &v)| (ObjectKey::from_raw(raw).to_string(), v)),
        );
        entries.sort();
        entries
    }

    /// Total stored bytes across all slots.
    pub fn stored_bytes(&self) -> u64 {
        let slots = self.slots.read().unwrap();
        let dense: u64 = slots
            .objects
            .iter()
            .filter_map(|slot| slot.lock().unwrap().as_ref().map(|o| o.bytes))
            .sum();
        dense
            + self
                .named_objects
                .lock()
                .unwrap()
                .values()
                .map(|o| o.bytes)
                .sum::<u64>()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::TaskId;

    fn store() -> Arc<KvStore> {
        KvStore::new(NetConfig::default(), Arc::new(MetricsHub::new()))
    }

    #[test]
    fn put_get_roundtrip() {
        crate::rt::run_virtual(async {
            let kv = store();
            let key = ObjectKey::output(TaskId(1));
            kv.put(key, DataObj::synthetic(1024), 1e9).await;
            let obj = kv.get(key, 1e9).await.unwrap();
            assert_eq!(obj.bytes, 1024);
            assert_eq!(kv.object_count(), 1);
            assert_eq!(kv.stored_bytes(), 1024);
        });
    }

    #[test]
    fn missing_key_errors() {
        crate::rt::run_virtual(async {
            let kv = store();
            let err = kv.get(ObjectKey::output(TaskId(9)), 1e9).await.unwrap_err();
            assert!(matches!(err, EngineError::MissingObject { .. }));
        });
    }

    #[test]
    fn incr_concurrent_fan_in_ends_exactly_at_1000() {
        // 1000 concurrent increments of one fan-in counter: every INCR
        // observes a distinct value and the counter ends exactly at 1000
        // — the atomicity the last-writer-continues rule rests on.
        crate::rt::run_virtual(async {
            let kv = store();
            let key = ObjectKey::counter(TaskId(3));
            let handles: Vec<_> = (0..1000)
                .map(|_| {
                    let kv = kv.clone();
                    crate::rt::spawn(async move { kv.incr(key).await })
                })
                .collect();
            let mut seen = Vec::with_capacity(1000);
            for h in handles {
                seen.push(h.await);
            }
            seen.sort_unstable();
            assert_eq!(seen, (1..=1000).collect::<Vec<u64>>());
            assert_eq!(kv.counter_value(key), 1000);
        });
    }

    #[test]
    fn contains_charges_a_round_trip() {
        crate::rt::run_virtual(async {
            let kv = store();
            let key = ObjectKey::output(TaskId(5));
            let t0 = clock::now();
            assert!(!kv.contains(key).await, "nothing stored yet");
            let dt = clock::now() - t0;
            // Default config: 300 µs one-way => 600 µs round trip.
            assert_eq!(dt, Duration::from_secs_f64(300.0 * 1e-6) * 2);
        });
    }

    #[test]
    fn contains_escape_hatch_is_free() {
        crate::rt::run_virtual(async {
            let cfg = NetConfig {
                charge_exists: false,
                ..NetConfig::default()
            };
            let kv = KvStore::new(cfg, Arc::new(MetricsHub::new()));
            let key = ObjectKey::output(TaskId(5));
            kv.put(key, DataObj::synthetic(8), 1e9).await;
            let t0 = clock::now();
            assert!(kv.contains(key).await);
            assert_eq!(clock::now(), t0, "charge_exists=false must be free");
            // The sync forensic probe is always free.
            assert!(kv.peek_contains(key));
            assert!(!kv.peek_contains(ObjectKey::output(TaskId(6))));
        });
    }

    #[test]
    fn dense_slots_presize_and_grow() {
        crate::rt::run_virtual(async {
            let kv = store();
            kv.ensure_task_capacity(16);
            kv.put(ObjectKey::output(TaskId(15)), DataObj::synthetic(1), 1e9)
                .await;
            // Beyond the pre-sized range: the cold growth path.
            kv.put(ObjectKey::output(TaskId(100)), DataObj::synthetic(2), 1e9)
                .await;
            assert_eq!(kv.incr(ObjectKey::counter(TaskId(200))).await, 1);
            assert_eq!(kv.object_count(), 2);
            assert_eq!(
                kv.object_keys(),
                vec!["out:100".to_string(), "out:15".to_string()]
            );
            assert_eq!(kv.counter_entries(), vec![("ctr:200".to_string(), 1)]);
        });
    }

    #[test]
    fn named_keys_use_the_side_map() {
        crate::rt::run_virtual(async {
            let kv = store();
            let k = ObjectKey::named("forensics:blob");
            kv.put(k, DataObj::synthetic(64), 1e9).await;
            assert!(kv.peek_contains(k));
            assert_eq!(kv.get(k, 1e9).await.unwrap().bytes, 64);
            assert_eq!(kv.incr(ObjectKey::named("forensics:ctr")).await, 1);
            assert_eq!(kv.incr(ObjectKey::named("forensics:ctr")).await, 2);
            assert_eq!(kv.counter_value(ObjectKey::named("forensics:ctr")), 2);
            assert_eq!(kv.object_count(), 1);
            assert!(kv.object_keys()[0].starts_with("key:"));
        });
    }

    #[test]
    fn transfers_cost_virtual_time() {
        crate::rt::run_virtual(async {
            let kv = store();
            let t0 = clock::now();
            kv.put(
                ObjectKey::output(TaskId(0)),
                DataObj::synthetic(100 * 1024 * 1024),
                75e6, // lambda NIC ~600 Mbps
            )
            .await;
            let dt = clock::now() - t0;
            // 100 MiB at 75 MB/s ≈ 1.4 s — must be visible in virtual time.
            assert!(dt > Duration::from_secs(1), "dt = {dt:?}");
        });
    }

    #[test]
    fn ideal_storage_is_free() {
        crate::rt::run_virtual(async {
            let kv = KvStore::with_ideal(NetConfig::default(), Arc::new(MetricsHub::new()), true);
            let t0 = clock::now();
            kv.put(
                ObjectKey::output(TaskId(0)),
                DataObj::synthetic(1 << 30),
                75e6,
            )
            .await;
            kv.get(ObjectKey::output(TaskId(0)), 75e6).await.unwrap();
            assert!(kv.contains(ObjectKey::output(TaskId(0))).await);
            assert_eq!(clock::now(), t0);
        });
    }

    #[test]
    fn shared_vm_contends() {
        crate::rt::run_virtual(async {
            // With all shards behind one NIC, two large transfers to different
            // keys serialize; with shard-per-VM they proceed in parallel.
            let metrics = Arc::new(MetricsHub::new());
            let mut cfg = NetConfig {
                kv_shared_vm: true,
                kv_latency_us: 0.0,
                ..NetConfig::default()
            };
            cfg.kv_bandwidth_bps = 1e6; // 1 MB/s to make it visible
            let shared = KvStore::new(cfg.clone(), metrics.clone());
            // Pick two keys that live on *different* shards so that the
            // shard-per-VM configuration can actually parallelize them.
            let (k1, k2) = {
                let probe = KvStore::new(
                    NetConfig {
                        kv_shared_vm: false,
                        ..NetConfig::default()
                    },
                    Arc::new(MetricsHub::new()),
                );
                let mut found = None;
                'outer: for i in 0..32u32 {
                    for j in (i + 1)..32 {
                        let a = ObjectKey::output(TaskId(i));
                        let b = ObjectKey::output(TaskId(j));
                        if !std::ptr::eq(probe.shard_of(a), probe.shard_of(b)) {
                            found = Some((a, b));
                            break 'outer;
                        }
                    }
                }
                found.expect("no shard-distinct key pair in 32 probes")
            };
            let t0 = clock::now();
            crate::rt::join_all(vec![
                shared.put(k1, DataObj::synthetic(1_000_000), 1e9),
                shared.put(k2, DataObj::synthetic(1_000_000), 1e9),
            ])
            .await;
            let shared_dt = clock::now() - t0;

            cfg.kv_shared_vm = false;
            let split = KvStore::new(cfg, metrics);
            let t1 = clock::now();
            crate::rt::join_all(vec![
                split.put(k1, DataObj::synthetic(1_000_000), 1e9),
                split.put(k2, DataObj::synthetic(1_000_000), 1e9),
            ])
            .await;
            let split_dt = clock::now() - t1;
            assert!(
                shared_dt > split_dt,
                "shared {shared_dt:?} vs split {split_dt:?}"
            );
        });
    }
}
