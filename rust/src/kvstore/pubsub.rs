//! Publish/subscribe channels (Redis PubSub equivalent, paper §III-B).
//!
//! The centralized designs subscribe the scheduler to completion channels;
//! WUKONG's storage manager subscribes its proxy to the large-fan-out
//! channel and the client subscribes to the final-result channel.

use crate::core::{ExecutorId, TaskId};
use std::collections::HashMap;
use std::sync::Mutex;
use crate::rt::sync::mpsc;

/// Messages carried over pub/sub channels.
#[derive(Clone, Debug)]
pub enum Message {
    /// A task finished (centralized designs: completion notification).
    TaskDone { task: TaskId, executor: ExecutorId },
    /// A large fan-out must be invoked by the proxy on behalf of an
    /// executor (paper §IV-D "Large Fan-out Task Invocations"). The payload
    /// identifies the fan-out's location in the DAG as a CSR out-edge
    /// range — three words instead of an owned `Vec<TaskId>`, so a
    /// width-10k fan-out publishes without copying its child list. The
    /// receiver resolves the children from its own copy of the DAG
    /// (which the storage manager received at job start).
    FanOutRequest {
        fan_out_task: TaskId,
        /// First index within `dag.children(fan_out_task)` to invoke
        /// (the executor keeps edge 0 for itself).
        from_edge: u32,
        /// One past the last out-edge index to invoke.
        to_edge: u32,
    },
    /// A final (sink) task's result key is available.
    FinalResult { task: TaskId },
    /// Job-level failure broadcast.
    JobFailed { reason: String },
}

/// A subscription handle: an unbounded receiver of channel messages.
pub struct Subscription {
    rx: mpsc::Receiver<Message>,
}

impl Subscription {
    /// Awaits the next message (None if all publishers dropped).
    pub async fn recv(&mut self) -> Option<Message> {
        self.rx.recv().await
    }
}

/// The channel registry. Publishing is instantaneous at the broker; the
/// delivery latency is charged by the KV store front end (see
/// `KvStore::publish`), matching Redis PubSub's near-wire-speed delivery.
#[derive(Default)]
pub struct PubSub {
    channels: Mutex<HashMap<String, Vec<mpsc::Sender<Message>>>>,
}

impl std::fmt::Debug for PubSub {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "PubSub({} channels)", self.channels.lock().unwrap().len())
    }
}

impl PubSub {
    pub fn new() -> Self {
        Self::default()
    }

    /// Subscribes to `channel`, returning the receiving handle.
    pub fn subscribe(&self, channel: &str) -> Subscription {
        let (tx, rx) = mpsc::unbounded();
        self.channels
            .lock()
            .unwrap()
            .entry(channel.to_string())
            .or_default()
            .push(tx);
        Subscription { rx }
    }

    /// Delivers `msg` to all current subscribers of `channel`. Returns the
    /// number of subscribers reached.
    pub fn publish(&self, channel: &str, msg: Message) -> usize {
        let mut map = self.channels.lock().unwrap();
        let Some(subs) = map.get_mut(channel) else {
            return 0;
        };
        // Drop closed subscriptions as we go.
        subs.retain(|tx| tx.send(msg.clone()).is_ok());
        subs.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn publish_reaches_all_subscribers() {
        crate::rt::run_virtual(async {
            let ps = PubSub::new();
            let mut s1 = ps.subscribe("done");
            let mut s2 = ps.subscribe("done");
            let n = ps.publish(
                "done",
                Message::TaskDone {
                    task: TaskId(1),
                    executor: ExecutorId(9),
                },
            );
            assert_eq!(n, 2);
            assert!(matches!(
                s1.recv().await,
                Some(Message::TaskDone { task: TaskId(1), .. })
            ));
            assert!(matches!(s2.recv().await, Some(Message::TaskDone { .. })));
        });
    }

    #[test]
    fn publish_to_empty_channel_is_zero() {
        crate::rt::run_virtual(async {
            let ps = PubSub::new();
            assert_eq!(
                ps.publish("nobody", Message::FinalResult { task: TaskId(0) }),
                0
            );
        });
    }

    #[test]
    fn dropped_subscriber_pruned() {
        crate::rt::run_virtual(async {
            let ps = PubSub::new();
            {
                let _s = ps.subscribe("c");
            } // dropped immediately
            let n = ps.publish("c", Message::FinalResult { task: TaskId(0) });
            assert_eq!(n, 0);
        });
    }
}
