//! Publish/subscribe channels (Redis PubSub equivalent, paper §III-B).
//!
//! The centralized designs subscribe the scheduler to completion channels;
//! WUKONG's storage manager subscribes its proxy to the large-fan-out
//! channel and the client subscribes to the final-result channel.
//!
//! Channels are **namespaced per job**: every subscribe/publish names the
//! [`JobId`] whose namespace it addresses, so two concurrent jobs using
//! the same well-known channel names (`wukong:final`, `wukong:fanout`,
//! `sched:done`) can never cross-deliver each other's messages. Before
//! this scoping existed, a second concurrent job's `FinalResult` would
//! have been delivered to the first job's client — a real latent bug the
//! single-job engines simply never triggered.
//!
//! **Delivery semantics under crash recovery:** publishes are
//! at-least-once. A lethal fault can kill a chain after it published but
//! before the platform saw the attempt complete, so the re-executed chain
//! publishes again; receivers (the driver completion loops, the fan-out
//! proxy) dedup by task identity, and `FanOutRequest` carries the
//! publisher's execution `epoch` so re-invoked children re-draw their
//! straggler jitter instead of replaying the original slow draw.

use crate::core::{EngineError, ExecutorId, JobId, TaskId};
use crate::rt::sync::mpsc;
use std::collections::HashMap;
use std::sync::Mutex;

/// Messages carried over pub/sub channels.
#[derive(Clone, Debug)]
pub enum Message {
    /// A task finished (centralized designs: completion notification).
    TaskDone { task: TaskId, executor: ExecutorId },
    /// A large fan-out must be invoked by the proxy on behalf of an
    /// executor (paper §IV-D "Large Fan-out Task Invocations"). The payload
    /// identifies the fan-out's location in the DAG as a CSR out-edge
    /// range — three words instead of an owned `Vec<TaskId>`, so a
    /// width-10k fan-out publishes without copying its child list. The
    /// receiver resolves the children from its own copy of the DAG
    /// (which the storage manager received at job start).
    FanOutRequest {
        fan_out_task: TaskId,
        /// First index within `dag.children(fan_out_task)` to invoke
        /// (the executor keeps edge 0 for itself).
        from_edge: u32,
        /// One past the last out-edge index to invoke.
        to_edge: u32,
        /// Execution epoch of the publishing chain — 0 on the first
        /// execution, bumped by every recovery/hedge re-dispatch so the
        /// delegated children's jitter draws are re-salted.
        epoch: u32,
    },
    /// A final (sink) task's result key is available.
    FinalResult { task: TaskId },
    /// Job-level failure broadcast, carrying the typed engine error so a
    /// terminal `RetriesExhausted` surfaces to the driver as itself
    /// rather than flattened into a string.
    JobFailed { error: EngineError },
}

/// A subscription handle: an unbounded receiver of channel messages.
pub struct Subscription {
    rx: mpsc::Receiver<Message>,
}

impl Subscription {
    /// Awaits the next message (None if all publishers dropped).
    pub async fn recv(&mut self) -> Option<Message> {
        self.rx.recv().await
    }
}

/// The channel registry, namespaced per job: `job -> channel -> senders`.
/// Publishing is instantaneous at the broker; the delivery latency is
/// charged by the KV store front end (see `JobArena::publish`), matching
/// Redis PubSub's near-wire-speed delivery. The two-level map keeps the
/// publish path allocation-free: the job lookup is an integer key and the
/// channel lookup borrows the `&str`.
#[derive(Default)]
pub struct PubSub {
    channels: Mutex<HashMap<u64, HashMap<String, Vec<mpsc::Sender<Message>>>>>,
}

impl std::fmt::Debug for PubSub {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "PubSub({} job namespaces)",
            self.channels.lock().unwrap().len()
        )
    }
}

impl PubSub {
    pub fn new() -> Self {
        Self::default()
    }

    /// Subscribes to `channel` within `job`'s namespace, returning the
    /// receiving handle.
    pub fn subscribe(&self, job: JobId, channel: &str) -> Subscription {
        let (tx, rx) = mpsc::unbounded();
        self.channels
            .lock()
            .unwrap()
            .entry(job.0)
            .or_default()
            .entry(channel.to_string())
            .or_default()
            .push(tx);
        Subscription { rx }
    }

    /// Drops `job`'s entire channel namespace (its receivers see the
    /// channel close). Called at job teardown so a long-running service
    /// does not accumulate one dead namespace per completed job.
    pub fn remove_job(&self, job: JobId) {
        self.channels.lock().unwrap().remove(&job.0);
    }

    /// Number of live job namespaces — the broker-side leak detector the
    /// substrate-emptiness invariant checks (zero once every job has
    /// been torn down).
    pub fn namespace_count(&self) -> usize {
        self.channels.lock().unwrap().len()
    }

    /// Delivers `msg` to all current subscribers of `channel` within
    /// `job`'s namespace. Returns the number of subscribers reached —
    /// never a subscriber of another job's channel of the same name.
    pub fn publish(&self, job: JobId, channel: &str, msg: Message) -> usize {
        let mut map = self.channels.lock().unwrap();
        let Some(chans) = map.get_mut(&job.0) else {
            return 0;
        };
        let Some(subs) = chans.get_mut(channel) else {
            return 0;
        };
        // Drop closed subscriptions as we go.
        subs.retain(|tx| tx.send(msg.clone()).is_ok());
        subs.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const JOB: JobId = JobId(0);

    #[test]
    fn publish_reaches_all_subscribers() {
        crate::rt::run_virtual(async {
            let ps = PubSub::new();
            let mut s1 = ps.subscribe(JOB, "done");
            let mut s2 = ps.subscribe(JOB, "done");
            let n = ps.publish(
                JOB,
                "done",
                Message::TaskDone {
                    task: TaskId(1),
                    executor: ExecutorId(9),
                },
            );
            assert_eq!(n, 2);
            assert!(matches!(
                s1.recv().await,
                Some(Message::TaskDone { task: TaskId(1), .. })
            ));
            assert!(matches!(s2.recv().await, Some(Message::TaskDone { .. })));
        });
    }

    #[test]
    fn publish_to_empty_channel_is_zero() {
        crate::rt::run_virtual(async {
            let ps = PubSub::new();
            assert_eq!(
                ps.publish(JOB, "nobody", Message::FinalResult { task: TaskId(0) }),
                0
            );
        });
    }

    #[test]
    fn dropped_subscriber_pruned() {
        crate::rt::run_virtual(async {
            let ps = PubSub::new();
            {
                let _s = ps.subscribe(JOB, "c");
            } // dropped immediately
            let n = ps.publish(JOB, "c", Message::FinalResult { task: TaskId(0) });
            assert_eq!(n, 0);
        });
    }

    #[test]
    fn jobs_never_cross_deliver_on_shared_channel_names() {
        // The latent multi-tenant bug this namespace exists to kill: two
        // jobs both use the well-known "wukong:final" channel name; each
        // client must see exactly its own job's FinalResult.
        crate::rt::run_virtual(async {
            let ps = PubSub::new();
            let mut a = ps.subscribe(JobId(1), "wukong:final");
            let mut b = ps.subscribe(JobId(2), "wukong:final");
            assert_eq!(
                ps.publish(JobId(1), "wukong:final", Message::FinalResult { task: TaskId(7) }),
                1,
                "job 1's publish must reach only job 1's subscriber"
            );
            assert_eq!(
                ps.publish(JobId(2), "wukong:final", Message::FinalResult { task: TaskId(9) }),
                1
            );
            assert!(matches!(
                a.recv().await,
                Some(Message::FinalResult { task: TaskId(7) })
            ));
            assert!(matches!(
                b.recv().await,
                Some(Message::FinalResult { task: TaskId(9) })
            ));
        });
    }
}
